//! Ingress codec ports (ISSUE 7, paper §4.4 — the encode half).
//!
//! The paper places LEXI codecs "at the ingress **and** egress ports of
//! network-on-chip routers"; PR 5 modeled only the egress decoder. This
//! module is the injection-side twin of [`crate::egress`]: every node's
//! network interface pushes codec-tagged flits through a per-node
//! **encoder occupancy** model driven by the `lexi-hw` cycle models —
//! [`lexi_hw::encoder::EncoderUnit`] for the steady-state rate (M
//! single-cycle LUT lanes → 1/M codec cycles per symbol) and
//! [`lexi_hw::compressor::CompressReport`] for the runtime-codebook
//! startup (histogram sampling + tree build + LUT programming), charged
//! once on the head flit of a runtime-Huffman packet.
//!
//! The arithmetic is shared with egress on purpose (`ready`/`accept`
//! re-exported from there) so `tools/logic_check.py` §[13] mirrors one
//! rule, not two:
//!
//! * a node's encoder owns a fractional `busy_until` horizon;
//! * a flit may inject in cycle `now` iff [`crate::egress::ready`] —
//!   otherwise the packet stays at the NI and the stall is counted
//!   (`SimStats::encode_stall_cycles`), never silently absorbed;
//! * an accepted flit advances the horizon by its encode cost
//!   ([`crate::egress::accept`]), the flit's symbol share through the
//!   encode lanes plus the compressor startup on a runtime-Huffman head.
//!
//! Backpressure is **bounded**: each NI holds at most
//! [`IngressCodecConfig::max_queue`] packets. Scheduled arrivals beyond
//! the bound are deferred (counted in `SimStats::injections_refused`),
//! and the closed-loop [`crate::Network::try_inject`] API refuses with a
//! typed [`lexi_core::error::Error::IngressSaturated`] so a traffic
//! generator sees the saturation instead of an unbounded `VecDeque`.

use crate::egress::{NOMINAL_CODEBOOK_STARTUP_NS, NOMINAL_LUT_FILL_CYCLES};
use crate::packet::CodecTag;
use lexi_core::codec::CodecKind;
use lexi_hw::compressor::CompressReport;
use lexi_hw::encoder::EncoderUnit;

/// Default bound on the per-node NI injection queue, in packets. Small
/// on purpose: the paper's ingress buffers are a handful of flit-depths,
/// and an encoder that falls behind should surface as refusals within a
/// few packets, not after megabytes of queueing.
pub const DEFAULT_MAX_QUEUE: usize = 8;

/// Ingress encoder parameters for one network. Rates are **effective
/// across all lanes** (codec cycles per symbol with every lane running),
/// indexed by [`CodecKind::wire_tag`], exactly like
/// [`crate::egress::EgressCodecConfig`].
#[derive(Clone, Copy, Debug)]
pub struct IngressCodecConfig {
    /// Parallel encode-LUT lanes at each sender (reporting only; the
    /// rates below already include lane parallelism).
    pub lanes: usize,
    /// Codec clock, GHz (converts codec cycles to ns).
    pub codec_ghz: f64,
    /// Effective encoder cycles per symbol per codec, all lanes
    /// combined, indexed by `CodecKind::wire_tag()`. Raw must be 0.
    pub cycles_per_symbol: [f64; 3],
    /// One-time startup charged on the head flit of each runtime-Huffman
    /// packet (histogram sampling + tree build + encode-LUT
    /// programming), ns. The *decode*-side LUT fill belongs to egress —
    /// when both port sets are installed, the pair together charges the
    /// engine's full `huffman_startup_ns()` exactly once.
    pub startup_ns: f64,
    /// Bound on the per-node NI injection queue, packets. Admission
    /// beyond this refuses (`Error::IngressSaturated`) — never grows.
    pub max_queue: usize,
}

impl IngressCodecConfig {
    /// Nominal rates: one symbol per lane per cycle on both Huffman and
    /// BDI (single-cycle LUT lookup / delta pack — the encode side has
    /// no probe-fill stall term, so there is no 1.16× analogue), free
    /// Raw. The startup is the codebook **pipeline** only (fixed ns,
    /// like `Engine::codec_startup_ns`): the decoder's LUT fill is
    /// egress's share of the split.
    pub fn nominal(lanes: usize, codec_ghz: f64) -> Self {
        let cps = EncoderUnit::new(lanes.max(1)).cycles_per_symbol();
        IngressCodecConfig {
            lanes: lanes.max(1),
            codec_ghz,
            cycles_per_symbol: [cps, cps, 0.0],
            startup_ns: NOMINAL_CODEBOOK_STARTUP_NS,
            max_queue: DEFAULT_MAX_QUEUE,
        }
    }

    /// The paper operating point: 10 encode lanes at 1 GHz (§4.3 —
    /// "ten lanes saturate the link").
    pub fn paper_default() -> Self {
        Self::nominal(10, 1.0)
    }

    /// Rates from a `lexi-hw` encoder unit (the exact reciprocal of its
    /// lane count — kept as a constructor so a future nonuniform
    /// encoder model slots in without touching callers).
    pub fn from_encoder(unit: &EncoderUnit, codec_ghz: f64) -> Self {
        let mut cfg = Self::nominal(unit.throughput(), codec_ghz);
        let cps = unit.cycles_per_symbol();
        cfg.cycles_per_symbol[CodecKind::Huffman.wire_tag() as usize] = cps;
        cfg.cycles_per_symbol[CodecKind::Bdi.wire_tag() as usize] = cps;
        cfg
    }

    /// Startup measured on the full `lexi-hw` compressor for a real
    /// stream: histogram + tree-build + LUT-program cycles at
    /// `codec_ghz`, replacing the nominal fixed-ns figure.
    pub fn with_measured_startup(mut self, report: &CompressReport) -> Self {
        self.startup_ns = report.startup_cycles as f64 / self.codec_ghz;
        self
    }

    /// Install an externally measured effective encode rate for one
    /// codec (cycles per symbol, all lanes combined).
    pub fn set_rate(&mut self, kind: CodecKind, cycles_per_symbol: f64) -> &mut Self {
        self.cycles_per_symbol[kind.wire_tag() as usize] = cycles_per_symbol;
        self
    }

    /// Encoder ns per symbol for `kind`, all lanes combined.
    #[inline]
    pub fn ns_per_symbol(&self, kind: CodecKind) -> f64 {
        self.cycles_per_symbol[kind.wire_tag() as usize] / self.codec_ghz
    }

    /// Encode cost of one flit of a tagged packet, in **network
    /// cycles**: the packet's symbols spread uniformly over its flits,
    /// plus the compressor startup on a runtime-Huffman head.
    /// (`charge_startup` is the head-flit test *and* the first-attempt
    /// test: a retransmission replays the already-encoded stream, so
    /// the codebook is not rebuilt.)
    pub fn flit_cost_cycles(
        &self,
        tag: &CodecTag,
        total_flits: u32,
        charge_startup: bool,
        cycle_ns: f64,
    ) -> f64 {
        let sym_share = tag.symbols as f64 / total_flits.max(1) as f64;
        let mut cost_ns = sym_share * self.ns_per_symbol(tag.kind);
        if charge_startup && tag.runtime_book && tag.kind == CodecKind::Huffman {
            cost_ns += self.startup_ns;
        }
        cost_ns / cycle_ns
    }
}

/// Per-node ingress encoder state (twin of [`crate::egress::EgressPort`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct IngressPort {
    /// Network cycle (fractional) at which the encoder's current backlog
    /// is fully drained.
    pub busy_until: f64,
    /// Injection attempts this port refused because the encoder was
    /// backlogged (aggregate over all packets at this node).
    pub stall_cycles: u64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::egress::{accept, ready};

    fn tag(kind: CodecKind, symbols: u64, runtime_book: bool) -> CodecTag {
        CodecTag {
            kind,
            symbols,
            runtime_book,
        }
    }

    /// Replay the accept/stall rule on a saturated injection port (a
    /// packet always waiting at the NI) and return
    /// (completion_cycle, stalls) — identical discipline to the egress
    /// drain helper, driven from the send side.
    fn drain(flits: u32, cost_body: f64, cost_head: f64) -> (u64, u64) {
        let (mut busy, mut now, mut stalls, mut sent) = (0.0f64, 0u64, 0u64, 0u32);
        while sent < flits {
            if ready(busy, now) {
                let c = if sent == 0 { cost_head } else { cost_body };
                busy = accept(busy, now, c);
                sent += 1;
            } else {
                stalls += 1;
            }
            now += 1;
        }
        (now.max(busy.ceil() as u64), stalls)
    }

    #[test]
    fn line_rate_encoder_never_stalls() {
        // cost ≤ 1 cycle/flit ⇒ injection stays at 1 flit/cycle — the
        // paper's "ten lanes saturate the link" operating point.
        for cost in [0.0, 0.25, 0.9, 1.0] {
            let (done, stalls) = drain(1000, cost, cost);
            assert_eq!(stalls, 0, "cost {cost}");
            assert_eq!(done, 1000, "cost {cost}");
        }
    }

    #[test]
    fn slow_encoder_throttles_fractionally() {
        // cost 1.5 ⇒ 2 flits per 3 cycles (fractional pacing, not ⌈1.5⌉).
        let (done, stalls) = drain(1000, 1.5, 1.5);
        assert!((done as f64 - 1500.0).abs() <= 2.0, "done {done}");
        assert!(stalls > 0);
    }

    #[test]
    fn startup_charged_once_on_head() {
        // Line-rate body, 133-cycle head startup (170 ns at the 1.28 ns
        // network cycle): completion = flits + startup.
        let (done, stalls) = drain(100, 1.0, 1.0 + 133.0);
        assert_eq!(done, 100 + 133);
        assert_eq!(stalls, 133);
    }

    #[test]
    fn paper_point_encodes_at_line_rate() {
        // 10 lanes at 1 GHz: ~13 symbols per 128-bit flit at the paper
        // wire ratio → 1.3 ns encode vs 1.28 ns flit time... just over;
        // the paper's own margin. At the honest per-flit share (~10
        // symbols per flit at wire ratio 10 bits/symbol) the cost is
        // 1.0 ns < 1.28 ns — line rate.
        let cfg = IngressCodecConfig::paper_default();
        let t = tag(CodecKind::Huffman, 10, false);
        let cost = cfg.flit_cost_cycles(&t, 1, false, 1.28);
        assert!(cost <= 1.0, "paper point stalls the link: {cost}");
        // One starved lane is 10× slower: visibly encode-bound.
        let one = IngressCodecConfig::nominal(1, 1.0);
        assert!(one.flit_cost_cycles(&t, 1, false, 1.28) > 5.0);
    }

    #[test]
    fn flit_cost_spreads_symbols_and_charges_startup_on_head_only() {
        let cfg = IngressCodecConfig::nominal(1, 1.0);
        let cycle_ns = 1.28;
        let t = tag(CodecKind::Huffman, 1000, true);
        let body = cfg.flit_cost_cycles(&t, 100, false, cycle_ns);
        let head = cfg.flit_cost_cycles(&t, 100, true, cycle_ns);
        // 10 symbols/flit × 1.0 ns/sym ÷ 1.28 ns/cycle.
        assert!((body - 10.0 / 1.28).abs() < 1e-9);
        assert!((head - body - NOMINAL_CODEBOOK_STARTUP_NS / 1.28).abs() < 1e-9);
        // Offline books (weights) and non-Huffman codecs skip startup;
        // Raw encodes free.
        let offline = tag(CodecKind::Huffman, 1000, false);
        assert_eq!(
            cfg.flit_cost_cycles(&offline, 100, true, cycle_ns),
            cfg.flit_cost_cycles(&offline, 100, false, cycle_ns)
        );
        let bdi = tag(CodecKind::Bdi, 1000, true);
        assert_eq!(
            cfg.flit_cost_cycles(&bdi, 100, true, cycle_ns),
            cfg.flit_cost_cycles(&bdi, 100, false, cycle_ns)
        );
        assert_eq!(
            cfg.flit_cost_cycles(&tag(CodecKind::Raw, 1000, false), 100, false, cycle_ns),
            0.0
        );
    }

    #[test]
    fn startup_split_sums_to_engine_startup() {
        // Ingress (codebook pipeline) + egress (LUT fill) must equal the
        // engine's one-shot huffman_startup_ns at every codec clock, so
        // a duplex replay charges the startup exactly once in total.
        for ghz in [0.5, 1.0, 2.0] {
            let i = IngressCodecConfig::nominal(10, ghz);
            let split = crate::egress::EgressCodecConfig::nominal(16, ghz).startup_ns
                - NOMINAL_CODEBOOK_STARTUP_NS; // egress's LUT-fill share
            assert!(
                (i.startup_ns + split
                    - (NOMINAL_CODEBOOK_STARTUP_NS + NOMINAL_LUT_FILL_CYCLES / ghz))
                    .abs()
                    < 1e-12
            );
        }
    }

    #[test]
    fn measured_encoder_and_compressor_install() {
        let unit = EncoderUnit::new(4);
        let cfg = IngressCodecConfig::from_encoder(&unit, 2.0);
        assert!((cfg.ns_per_symbol(CodecKind::Huffman) - 0.125).abs() < 1e-12);
        assert_eq!(cfg.ns_per_symbol(CodecKind::Raw), 0.0);
        // Measured startup replaces the nominal fixed-ns figure.
        let exps: Vec<u8> = (0..2000u32).map(|i| 120 + (i % 9) as u8).collect();
        let comp = lexi_hw::compressor::Compressor::new(
            lexi_hw::compressor::CompressorConfig::paper_default(),
        );
        let (_, _, report) = comp.compress(&exps).unwrap();
        let cfg = cfg.with_measured_startup(&report);
        assert!((cfg.startup_ns - report.startup_cycles as f64 / 2.0).abs() < 1e-12);
        assert!(cfg.startup_ns > 0.0 && cfg.startup_ns < 200.0);
    }
}
