//! Packets and flits.
//!
//! A message of `size_bits` becomes ⌈size/flit_bits⌉ flits framed
//! head/body/tail (or a single-flit packet). Wormhole switching reserves a
//! path per packet from head to tail.

use crate::topology::NodeId;

/// What position a flit holds in its packet.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FlitKind {
    Head,
    Body,
    Tail,
    /// Single-flit packet (head and tail at once).
    Single,
}

/// One flit in flight.
#[derive(Clone, Copy, Debug)]
pub struct Flit {
    pub packet_id: u64,
    pub kind: FlitKind,
    pub src: NodeId,
    pub dest: NodeId,
    /// Sequence inside the packet (0 = head).
    pub seq: u32,
    /// Cycle at which this flit may next move (prevents multi-hop/cycle).
    pub ready_at: u64,
}

impl Flit {
    /// Does this flit release the wormhole lock?
    #[inline]
    pub fn is_tail(&self) -> bool {
        matches!(self.kind, FlitKind::Tail | FlitKind::Single)
    }

    /// Does this flit acquire the wormhole lock?
    #[inline]
    pub fn is_head(&self) -> bool {
        matches!(self.kind, FlitKind::Head | FlitKind::Single)
    }
}

/// An injection request: one message on the NoI.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PacketSpec {
    pub src: NodeId,
    pub dest: NodeId,
    /// Message size in bits (payload incl. any codec headers).
    pub size_bits: u64,
    /// Earliest injection cycle.
    pub inject_at: u64,
}

impl PacketSpec {
    /// Number of flits for a given flit width.
    pub fn flits(&self, flit_bits: u32) -> u32 {
        (self.size_bits.div_ceil(flit_bits as u64)).max(1) as u32
    }
}

/// Per-packet completion record.
#[derive(Clone, Copy, Debug)]
pub struct PacketRecord {
    pub spec: PacketSpec,
    pub inject_cycle: u64,
    pub eject_cycle: u64,
    pub flits: u32,
}

impl PacketRecord {
    /// End-to-end latency in cycles (inject of head → eject of tail).
    pub fn latency(&self) -> u64 {
        self.eject_cycle - self.inject_cycle
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flit_count() {
        let p = PacketSpec {
            src: NodeId(0),
            dest: NodeId(1),
            size_bits: 129,
            inject_at: 0,
        };
        assert_eq!(p.flits(128), 2);
        let q = PacketSpec { size_bits: 128, ..p };
        assert_eq!(q.flits(128), 1);
        let z = PacketSpec { size_bits: 0, ..p };
        assert_eq!(z.flits(128), 1);
    }
}
