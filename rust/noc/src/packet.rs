//! Packets and flits.
//!
//! A message of `size_bits` becomes ⌈size/flit_bits⌉ flits framed
//! head/body/tail (or a single-flit packet). Wormhole switching reserves a
//! path per packet from head to tail.
//!
//! **Codec tags (ISSUE 5):** a packet may carry a [`CodecTag`] naming the
//! exponent codec its payload travels under and how many exponent symbols
//! the egress decoder must emit. Tagged flits drain through the per-node
//! [`EgressCodec`](crate::egress) port at the measured decoder rate
//! instead of the codec-blind 1 flit/cycle; untagged packets (and any
//! network built without an egress config) keep the legacy behaviour.

use crate::topology::NodeId;
use lexi_core::codec::CodecKind;

/// Per-packet codec metadata carried on the wire (head-flit header in the
/// real format; a struct field in the simulator).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CodecTag {
    /// Which exponent codec encoded the payload.
    pub kind: CodecKind,
    /// Exponent symbols the egress decoder emits for this packet. Must
    /// not exceed `size_bits` (every coded symbol costs ≥ 1 wire bit);
    /// violations are rejected at scheduling, not mis-charged.
    pub symbols: u64,
    /// The codebook ships with the data (runtime compression): the
    /// egress decoder pays the codebook-pipeline + multi-symbol-LUT-fill
    /// startup before draining. Only meaningful for Huffman; weights
    /// (offline-compressed, LUTs stream in with the data) set it false.
    pub runtime_book: bool,
}

/// What position a flit holds in its packet.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FlitKind {
    Head,
    Body,
    Tail,
    /// Single-flit packet (head and tail at once).
    Single,
}

/// One flit in flight.
#[derive(Clone, Copy, Debug)]
pub struct Flit {
    pub packet_id: u64,
    pub kind: FlitKind,
    pub src: NodeId,
    pub dest: NodeId,
    /// Sequence inside the packet (0 = head).
    pub seq: u32,
    /// Virtual channel the flit currently occupies (ISSUE 10): the
    /// index of the per-VC input FIFO it sits in. VC 0 is the
    /// deadlock-free up*/down* escape channel; VCs ≥ 1 route
    /// adaptively. Single-VC networks keep every flit on VC 0.
    pub vc: u8,
    /// Cycle at which this flit may next move (prevents multi-hop/cycle).
    pub ready_at: u64,
    /// Codec tag inherited from the packet spec (`None` = codec-blind
    /// raw payload, ejected at the legacy 1 flit/cycle).
    pub codec: Option<CodecTag>,
}

impl Flit {
    /// Does this flit release the wormhole lock?
    #[inline]
    pub fn is_tail(&self) -> bool {
        matches!(self.kind, FlitKind::Tail | FlitKind::Single)
    }

    /// Does this flit acquire the wormhole lock?
    #[inline]
    pub fn is_head(&self) -> bool {
        matches!(self.kind, FlitKind::Head | FlitKind::Single)
    }
}

/// An injection request: one message on the NoI.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PacketSpec {
    pub src: NodeId,
    pub dest: NodeId,
    /// Message size in bits (payload incl. any codec headers).
    pub size_bits: u64,
    /// Earliest injection cycle.
    pub inject_at: u64,
    /// Codec tag (`None` = raw codec-blind packet).
    pub codec: Option<CodecTag>,
    /// Pin the injection virtual channel (ISSUE 10, clamped to the
    /// network's `vcs − 1`). `None` picks the default policy: VC 0 on
    /// single-VC networks, an adaptive VC (≥ 1) spread by packet id
    /// otherwise. Tests and tools use the pin to place traffic on a
    /// specific channel.
    pub vc: Option<u8>,
}

impl PacketSpec {
    /// An untagged (codec-blind) packet.
    pub fn new(src: NodeId, dest: NodeId, size_bits: u64, inject_at: u64) -> Self {
        PacketSpec {
            src,
            dest,
            size_bits,
            inject_at,
            codec: None,
            vc: None,
        }
    }

    /// The same packet carrying a codec tag.
    pub fn tagged(self, tag: CodecTag) -> Self {
        PacketSpec {
            codec: Some(tag),
            ..self
        }
    }

    /// The same packet pinned to injection VC `vc`.
    pub fn on_vc(self, vc: u8) -> Self {
        PacketSpec {
            vc: Some(vc),
            ..self
        }
    }

    /// Number of flits for a given flit width.
    pub fn flits(&self, flit_bits: u32) -> u32 {
        (self.size_bits.div_ceil(flit_bits as u64)).max(1) as u32
    }
}

/// Per-packet completion record.
#[derive(Clone, Copy, Debug)]
pub struct PacketRecord {
    pub spec: PacketSpec,
    /// Cycle the head flit actually entered the network (NOT the
    /// scheduled `spec.inject_at`: source-side NI queueing between the
    /// two is reported separately by [`PacketRecord::queueing_delay`]).
    pub inject_cycle: u64,
    /// Cycle after which the tail has fully left the network — for
    /// codec-tagged packets this includes the egress decoder finishing
    /// the tail flit's symbols.
    pub eject_cycle: u64,
    pub flits: u32,
    /// Ejection cycles this packet's flits spent blocked behind its
    /// egress decoder (startup + drain backpressure). 0 for untagged
    /// packets and codec-blind networks.
    pub decode_stall_cycles: u64,
    /// Injection cycles this packet spent blocked behind its ingress
    /// encoder (ISSUE 7: compressor startup + encode-rate
    /// backpressure). 0 for untagged packets and networks without
    /// ingress codec ports. These cycles land in `queueing_delay` (the
    /// head hasn't entered the network yet) or inside `latency` for
    /// mid-packet stalls.
    pub encode_stall_cycles: u64,
    /// Retransmissions this packet needed before its CRC-clean delivery
    /// (ISSUE 6). Each retry's backoff + repeat trip is inside
    /// `eject_cycle − inject_cycle`, so latency never hides recovery.
    pub retries: u32,
}

impl PacketRecord {
    /// End-to-end network latency in cycles (actual inject of head →
    /// eject of tail). Source-side queueing is *excluded* — see
    /// [`PacketRecord::queueing_delay`].
    pub fn latency(&self) -> u64 {
        self.eject_cycle - self.inject_cycle
    }

    /// Cycles the packet waited at its source NI between its scheduled
    /// `inject_at` and the head flit actually entering the network.
    pub fn queueing_delay(&self) -> u64 {
        self.inject_cycle - self.spec.inject_at
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flit_count() {
        let p = PacketSpec::new(NodeId(0), NodeId(1), 129, 0);
        assert_eq!(p.flits(128), 2);
        let q = PacketSpec { size_bits: 128, ..p };
        assert_eq!(q.flits(128), 1);
        let z = PacketSpec { size_bits: 0, ..p };
        assert_eq!(z.flits(128), 1);
    }

    #[test]
    fn tagging_is_additive() {
        let tag = CodecTag {
            kind: CodecKind::Huffman,
            symbols: 64,
            runtime_book: true,
        };
        let p = PacketSpec::new(NodeId(0), NodeId(1), 4096, 7).tagged(tag);
        assert_eq!(p.codec, Some(tag));
        assert_eq!(p.size_bits, 4096);
        assert_eq!(p.inject_at, 7);
    }

    #[test]
    fn record_separates_queueing_from_latency() {
        let spec = PacketSpec::new(NodeId(0), NodeId(1), 128, 10);
        let rec = PacketRecord {
            spec,
            inject_cycle: 14, // head waited 4 cycles behind another packet
            eject_cycle: 20,
            flits: 1,
            decode_stall_cycles: 0,
            encode_stall_cycles: 0,
            retries: 0,
        };
        assert_eq!(rec.latency(), 6);
        assert_eq!(rec.queueing_delay(), 4);
    }
}
