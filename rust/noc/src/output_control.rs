//! Output control: switch allocation + wormhole lock management per
//! output port (ISSUE 10, the bsg_wormhole_router-style output side).
//!
//! Each output port arbitrates over the *flattened* candidate space of
//! `(input port × input VC)` lanes — `flat = inp * vcs + in_vc` — with
//! one round-robin pointer per output, advanced only when a tail
//! releases the port (exactly the legacy per-port pointer once
//! `vcs = 1` collapses the flat space to `NUM_PORTS` indices). One flit
//! crosses each physical output per cycle, and one flit leaves each
//! physical input per cycle (`input_taken`, iSLIP-lite); with a single
//! VC the latter is a no-op because each input's sole head-of-line flit
//! targets exactly one output.
//!
//! Grants are issued **regardless of downstream credits** — the
//! traversal stage declines a zero-credit grant without mutating
//! anything, so arbitration replays identically next cycle. This
//! mirrors the legacy router bit-for-bit and is what the `vcs = 1`
//! stat-identity property test pins.

use crate::packet::Flit;
use crate::topology::{Port, NUM_PORTS};
use crate::vc::{VcOutput, VcRouter, MAX_VCS};

/// One switch grant: the input lane that crosses an output this cycle,
/// and the output VC (credit lane) it consumes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Grant {
    /// Input port the flit pops from.
    pub inp: usize,
    /// Input VC the flit pops from.
    pub invc: u8,
    /// Output VC whose lane (lock + credits) the flit uses downstream.
    pub out_vc: u8,
}

/// Switch allocation for one router: for every output port, the first
/// eligible `(input port, input VC)` in flat round-robin order from the
/// output's pointer.
///
/// Eligibility of a head-of-line flit (must be `ready_at <= now`):
/// its `desired(inp, in_vc, flit, outputs)` names this output on some
/// output VC whose lane is either *held by this very lane for this very
/// packet* (wormhole continuation) or *free and the flit is a head*
/// (new lock, acquired at traversal). Inputs already granted to an
/// earlier output this cycle are skipped.
///
/// Pure: `&VcRouter` only, so a grant later declined (no credit, egress
/// backpressure, fault) recomputes identically.
pub fn arbitrate_all(
    router: &VcRouter,
    now: u64,
    desired: impl Fn(usize, u8, &Flit, &[VcOutput; NUM_PORTS]) -> Option<(Port, u8)>,
) -> [Option<Grant>; NUM_PORTS] {
    let vcs = router.vcs() as usize;
    let flat_len = NUM_PORTS * vcs;
    // Route each head-of-line flit exactly once (§Perf — same cost
    // profile as the legacy per-input request vector), then let outputs
    // consult the cached requests: requests[flat] = (want, out VC,
    // is_head, packet_id).
    let mut requests: [Option<(Port, u8, bool, u64)>; NUM_PORTS * MAX_VCS as usize] =
        [None; NUM_PORTS * MAX_VCS as usize];
    for inp in 0..NUM_PORTS {
        for invc in 0..vcs {
            let Some(hol) = router.inputs[inp].fifos[invc].front() else {
                continue;
            };
            if hol.ready_at > now {
                continue;
            }
            if let Some((want, ovc)) = desired(inp, invc as u8, hol, &router.outputs) {
                requests[inp * vcs + invc] = Some((want, ovc, hol.is_head(), hol.packet_id));
            }
        }
    }
    let mut grants = [None; NUM_PORTS];
    let mut input_taken = [false; NUM_PORTS];
    for out in Port::ALL {
        let start = router.outputs[out as usize].rr;
        for step in 0..flat_len {
            let flat = (start + step) % flat_len;
            let (inp, invc) = (flat / vcs, (flat % vcs) as u8);
            if input_taken[inp] {
                continue;
            }
            let Some((want, ovc, is_head, pid)) = requests[flat] else {
                continue;
            };
            if want != out {
                continue;
            }
            let lane = &router.outputs[out as usize].lanes[ovc as usize];
            let eligible = match lane.locked_to {
                Some(holder) => holder == (inp, invc) && lane.locked_packet == Some(pid),
                None => is_head,
            };
            if !eligible {
                continue;
            }
            grants[out as usize] = Some(Grant {
                inp,
                invc,
                out_vc: ovc,
            });
            input_taken[inp] = true;
            break;
        }
    }
    grants
}

/// Lock bookkeeping after a flit actually traverses `output` on lane
/// `out_vc`, having popped from `(inp, invc)`: a tail releases the lane
/// and advances the output's flat round-robin pointer past the winner;
/// any other flit (re)asserts the lane's wormhole lock. With `vcs = 1`
/// the pointer update reduces to the legacy `(inp + 1) % NUM_PORTS`.
pub fn update_lock(output: &mut VcOutput, out_vc: u8, inp: usize, invc: u8, flit: &Flit, vcs: u8) {
    let lane = &mut output.lanes[out_vc as usize];
    if flit.is_tail() {
        lane.locked_to = None;
        lane.locked_packet = None;
        output.rr = (inp * vcs as usize + invc as usize + 1) % (NUM_PORTS * vcs as usize);
    } else {
        lane.locked_to = Some((inp, invc));
        lane.locked_packet = Some(flit.packet_id);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::packet::FlitKind;
    use crate::topology::NodeId;

    fn flit(packet_id: u64, kind: FlitKind, seq: u32, vc: u8) -> Flit {
        Flit {
            packet_id,
            kind,
            src: NodeId(0),
            dest: NodeId(1),
            seq,
            vc,
            ready_at: 0,
            codec: None,
        }
    }

    /// Everything wants East on its own VC index — the scripted routing
    /// used by the contention scenario below and its Python mirror.
    fn to_east(_inp: usize, invc: u8, _f: &Flit, _o: &[VcOutput; NUM_PORTS]) -> Option<(Port, u8)> {
        Some((Port::East, invc))
    }

    /// The scripted 2-VC contention scenario, mirrored **verbatim** by
    /// `tools/logic_check.py` §[16]: one router, `vcs = 2`,
    /// `buf_depth = 4` (so each East lane holds 2 credits).
    ///
    /// * North VC 0: packet 1, a Single flit.
    /// * North VC 1: packet 2, a 3-flit worm (Head/Body/Tail).
    /// * West  VC 1: packet 3, a 3-flit worm.
    ///
    /// Scripted downstream credit returns on East VC 1: +1 at cycle 4,
    /// +1 at cycle 6, +2 at cycle 8. Expected per-cycle trace
    /// (granted inp, granted invc, traversed?, East vc0/vc1 credits
    /// after, East rr after):
    ///
    /// ```text
    /// cyc 0: (1,0) traverse  credits 1/2  rr 3   (Single: rr hops past flat 2)
    /// cyc 1: (1,1) traverse  credits 1/1  rr 3   (A head locks East vc1)
    /// cyc 2: (1,1) traverse  credits 1/0  rr 3
    /// cyc 3: (1,1) DECLINED  credits 1/0  rr 3   (grant stands, zero credits)
    /// cyc 4: (1,1) traverse  credits 1/0  rr 4   (A tail frees lane, rr past flat 3)
    /// cyc 5: (4,1) DECLINED  credits 1/0  rr 4   (B head granted, no credit yet)
    /// cyc 6: (4,1) traverse  credits 1/0  rr 4   (B head locks East vc1)
    /// cyc 7: (4,1) DECLINED  credits 1/0  rr 4
    /// cyc 8: (4,1) traverse  credits 1/1  rr 4
    /// cyc 9: (4,1) traverse  credits 1/0  rr 0   (B tail, rr past flat 9)
    /// ```
    #[test]
    fn scripted_two_vc_contention_trace() {
        let mut r = VcRouter::new(4, 2);
        let (n, w) = (Port::North as usize, Port::West as usize);
        r.inputs[n].fifos[0].push_back(flit(1, FlitKind::Single, 0, 0));
        for (seq, kind) in [(0, FlitKind::Head), (1, FlitKind::Body), (2, FlitKind::Tail)] {
            r.inputs[n].fifos[1].push_back(flit(2, kind, seq, 1));
            r.inputs[w].fifos[1].push_back(flit(3, kind, seq, 1));
        }

        // (cycle, credit return on East vc1 before arbitration,
        //  expected granted (inp, invc), traversed?, credits vc0/vc1
        //  after, rr after)
        let script: [(u64, u32, (usize, u8), bool, u32, u32, usize); 10] = [
            (0, 0, (n, 0), true, 1, 2, 3),
            (1, 0, (n, 1), true, 1, 1, 3),
            (2, 0, (n, 1), true, 1, 0, 3),
            (3, 0, (n, 1), false, 1, 0, 3),
            (4, 1, (n, 1), true, 1, 0, 4),
            (5, 0, (w, 1), false, 1, 0, 4),
            (6, 1, (w, 1), true, 1, 0, 4),
            (7, 0, (w, 1), false, 1, 0, 4),
            (8, 2, (w, 1), true, 1, 1, 4),
            (9, 0, (w, 1), true, 1, 0, 0),
        ];
        let e = Port::East as usize;
        let mut forwarded = 0u64;
        for (cyc, ret, want_grant, traversed, c0, c1, rr_after) in script {
            r.outputs[e].lanes[1].credits += ret;
            let grants = arbitrate_all(&r, cyc, to_east);
            let g = grants[e].unwrap_or_else(|| panic!("cycle {cyc}: expected a grant"));
            assert_eq!((g.inp, g.invc), want_grant, "cycle {cyc}: grant");
            assert_eq!(g.out_vc, g.invc, "scripted routing keeps the VC index");
            // Traversal stage: decline on zero credits, else pop +
            // charge the lane + update the lock.
            if r.outputs[e].lanes[g.out_vc as usize].credits == 0 {
                assert!(!traversed, "cycle {cyc}: should have been declined");
            } else {
                assert!(traversed, "cycle {cyc}: should have traversed");
                let f = r.inputs[g.inp].fifos[g.invc as usize].pop_front().unwrap();
                r.outputs[e].lanes[g.out_vc as usize].credits -= 1;
                r.outputs[e].forwarded += 1;
                forwarded += 1;
                update_lock(&mut r.outputs[e], g.out_vc, g.inp, g.invc, &f, 2);
            }
            assert_eq!(r.outputs[e].lanes[0].credits, c0, "cycle {cyc}: vc0 credits");
            assert_eq!(r.outputs[e].lanes[1].credits, c1, "cycle {cyc}: vc1 credits");
            assert_eq!(r.outputs[e].rr, rr_after, "cycle {cyc}: rr");
        }
        assert_eq!(forwarded, 7, "1 single + two 3-flit worms");
        assert!(r.is_idle());
        assert!(r.outputs[e].lanes[1].locked_to.is_none());
    }

    #[test]
    fn vc1_rr_advance_matches_legacy_pointer() {
        let mut r = VcRouter::new(4, 1);
        let tail = flit(9, FlitKind::Tail, 2, 0);
        // Legacy: tail from input `inp` sets rr = (inp + 1) % NUM_PORTS.
        for inp in 0..NUM_PORTS {
            update_lock(&mut r.outputs[Port::East as usize], 0, inp, 0, &tail, 1);
            assert_eq!(r.outputs[Port::East as usize].rr, (inp + 1) % NUM_PORTS);
        }
        // Non-tails lock without moving the pointer.
        let body = flit(9, FlitKind::Body, 1, 0);
        update_lock(&mut r.outputs[Port::East as usize], 0, 2, 0, &body, 1);
        assert_eq!(r.outputs[Port::East as usize].rr, 0);
        assert_eq!(
            r.outputs[Port::East as usize].lanes[0].locked_to,
            Some((2, 0))
        );
    }

    #[test]
    fn one_grant_per_input_port_per_cycle() {
        // North VC 0 wants East, North VC 1 wants West: the physical
        // North input can pop only one flit per cycle, and East
        // arbitrates first (Port::ALL order), so West goes ungranted.
        let mut r = VcRouter::new(4, 2);
        let n = Port::North as usize;
        r.inputs[n].fifos[0].push_back(flit(1, FlitKind::Single, 0, 0));
        r.inputs[n].fifos[1].push_back(flit(2, FlitKind::Single, 0, 1));
        let route = |_inp: usize, invc: u8, _f: &Flit, _o: &[VcOutput; NUM_PORTS]| {
            Some(if invc == 0 {
                (Port::East, 0u8)
            } else {
                (Port::West, 1u8)
            })
        };
        let grants = arbitrate_all(&r, 0, route);
        assert_eq!(
            grants[Port::East as usize],
            Some(Grant {
                inp: n,
                invc: 0,
                out_vc: 0
            })
        );
        assert_eq!(grants[Port::West as usize], None, "input already taken");
    }

    #[test]
    fn locked_lane_excludes_other_worms_and_future_flits_wait() {
        let mut r = VcRouter::new(4, 2);
        let (n, w, e) = (Port::North as usize, Port::West as usize, Port::East as usize);
        // East VC 1 locked to (North, VC 1) for packet 2.
        r.outputs[e].lanes[1].locked_to = Some((n, 1));
        r.outputs[e].lanes[1].locked_packet = Some(2);
        // West VC 1 head wants the same lane: excluded.
        r.inputs[w].fifos[1].push_back(flit(3, FlitKind::Head, 0, 1));
        let grants = arbitrate_all(&r, 0, to_east);
        assert_eq!(grants[e], None);
        // The lock holder's continuation flit wins it back…
        r.inputs[n].fifos[1].push_back(flit(2, FlitKind::Body, 1, 1));
        let grants = arbitrate_all(&r, 0, to_east);
        assert_eq!(
            grants[e],
            Some(Grant {
                inp: n,
                invc: 1,
                out_vc: 1
            })
        );
        // …unless it is not ready yet (in-flight on the upstream wire).
        r.inputs[n].fifos[1].front_mut().unwrap().ready_at = 5;
        let grants = arbitrate_all(&r, 0, to_east);
        assert_eq!(grants[e], None, "not ready, and the other worm stays shut out");
    }
}
