use super::*;
use crate::topology::{CMesh, MultiPackage};

fn cfg_4x4() -> NetworkConfig {
    NetworkConfig::for_topo(Topo::Mesh(Mesh::new(4, 4)))
}

fn run_after(mut net: Network, specs: &[PacketSpec]) -> (SimStats, Network) {
    net.schedule_packets(specs);
    let stats = net.run_to_completion(1_000_000);
    (stats, net)
}

#[test]
fn single_packet_minimal_latency() {
    let mut net = Network::new(cfg_4x4());
    let spec = PacketSpec::new(NodeId(0), NodeId(3), 128 * 4, 0); // 3 hops east
    net.schedule_packets(&[spec]);
    let stats = net.run_to_completion(1000);
    assert_eq!(stats.delivered_packets, 1);
    let rec = net.records[0];
    // Lower bound: injection (1) + hops (3) + serialization (3 more
    // flits) + ejection; exact value depends on the pipeline model —
    // assert a tight band, not an exact constant.
    let lb = 3 + 4 - 1;
    assert!(
        (lb..lb + 8).contains(&rec.latency()),
        "latency {}",
        rec.latency()
    );
    // No contention: the head injects the cycle it is scheduled.
    assert_eq!(rec.queueing_delay(), 0);
}

#[test]
fn self_send_delivers() {
    let mut net = Network::new(cfg_4x4());
    net.schedule_packets(&[PacketSpec::new(NodeId(5), NodeId(5), 64, 0)]);
    let stats = net.run_to_completion(100);
    assert_eq!(stats.delivered_packets, 1);
}

#[test]
fn all_packets_delivered_under_load() {
    let mut specs = Vec::new();
    for i in 0..16u16 {
        for j in 0..16u16 {
            if i != j {
                specs.push(PacketSpec::new(NodeId(i), NodeId(j), 128 * 3, (i as u64) * 2));
            }
        }
    }
    let n = specs.len() as u64;
    let (stats, _) = run_after(Network::new(cfg_4x4()), &specs);
    assert_eq!(stats.delivered_packets, n);
    assert_eq!(stats.delivered_flits, n * 3);
}

#[test]
fn congestion_raises_latency() {
    // Hotspot: everyone sends to node 0 — latency must exceed the
    // uncongested single-sender case.
    let (solo, _) = run_after(
        Network::new(cfg_4x4()),
        &[PacketSpec::new(NodeId(15), NodeId(0), 128 * 16, 0)],
    );
    let specs: Vec<PacketSpec> = (1..16u16)
        .map(|i| PacketSpec::new(NodeId(i), NodeId(0), 128 * 16, 0))
        .collect();
    let (hot, _) = run_after(Network::new(cfg_4x4()), &specs);
    assert!(hot.avg_latency() > solo.avg_latency() * 2.0);
}

#[test]
fn cycle_ns_matches_paper_link() {
    let cfg = NetworkConfig::paper_default();
    assert!((cfg.cycle_ns() - 1.28).abs() < 1e-9);
}

#[test]
fn queueing_delay_excluded_from_latency() {
    // Regression (ISSUE 5 satellite): two packets from one source —
    // the second's head cannot inject until the first's 8 flits have
    // cleared the NI, and that wait must land in queueing_delay, not
    // in latency.
    let a = PacketSpec::new(NodeId(0), NodeId(3), 128 * 8, 0);
    let (stats, net) = run_after(Network::new(cfg_4x4()), &[a, a]);
    assert_eq!(stats.delivered_packets, 2);
    let first = net.records.iter().find(|r| r.queueing_delay() == 0).unwrap();
    let second = net.records.iter().find(|r| r.queueing_delay() > 0).unwrap();
    assert!(
        second.latency() <= first.latency() + 2,
        "queueing leaked into latency: first {} vs second {}",
        first.latency(),
        second.latency()
    );
    assert!(
        (6..=10).contains(&second.queueing_delay()),
        "queueing {}",
        second.queueing_delay()
    );
    assert_eq!(
        stats.sum_queueing,
        net.records.iter().map(|r| r.queueing_delay()).sum::<u64>()
    );
}

// ------------------------------------------------------------------
// ISSUE 10: virtual channels
// ------------------------------------------------------------------

fn uniform_specs() -> Vec<PacketSpec> {
    let mut specs = Vec::new();
    for k in 0..300u64 {
        let (s, d) = ((k * 7 % 16) as u16, ((k * 11 + 3) % 16) as u16);
        if s != d {
            specs.push(PacketSpec::new(NodeId(s), NodeId(d), 128 * 6, k / 2));
        }
    }
    specs
}

#[test]
fn multi_vc_delivers_all_with_clean_per_vc_audit() {
    for vcs in [2u8, 4] {
        let specs = uniform_specs();
        let n = specs.len() as u64;
        let mut net = Network::new(cfg_4x4().with_vcs(vcs));
        net.schedule_packets(&specs);
        while !net.drained() {
            assert!(net.now() < 200_000, "vcs={vcs} failed to drain");
            net.step();
            let v = net.audit_credits();
            assert!(v.is_empty(), "vcs={vcs} violation at {}: {:?}", net.now(), v[0]);
        }
        let stats = net.stats();
        assert_eq!(stats.delivered_packets, n, "vcs={vcs}");
        // Per-VC accounting covers every hop and delivery.
        let usage = net.vc_usage();
        assert_eq!(usage.len(), vcs as usize);
        assert_eq!(
            usage.iter().map(|u| u.flit_hops).sum::<u64>(),
            stats.flit_hops
        );
        assert_eq!(
            usage.iter().map(|u| u.delivered_flits).sum::<u64>(),
            stats.delivered_flits
        );
        assert_eq!(usage.iter().map(|u| u.buffered).sum::<u64>(), 0);
        // The adaptive spread used more than one VC.
        assert!(
            usage[1..].iter().filter(|u| u.delivered_flits > 0).count() >= 1,
            "adaptive VCs unused"
        );
    }
}

#[test]
fn pinned_vc_traffic_stays_on_its_channel() {
    // A single uncontended worm pinned to VC 1 never needs the
    // escape fallback: all hops and deliveries land on VC 1.
    let spec = PacketSpec::new(NodeId(0), NodeId(15), 128 * 8, 0).on_vc(1);
    let (stats, net) = run_after(Network::new(cfg_4x4().with_vcs(2)), &[spec]);
    assert_eq!(stats.delivered_packets, 1);
    let usage = net.vc_usage();
    assert_eq!(usage[0].flit_hops, 0, "escape channel must stay idle");
    assert_eq!(usage[0].delivered_flits, 0);
    assert_eq!(usage[1].flit_hops, stats.flit_hops);
    assert_eq!(usage[1].delivered_flits, stats.delivered_flits);
    // An out-of-range pin clamps instead of panicking.
    let clamped = PacketSpec::new(NodeId(0), NodeId(3), 128, 0).on_vc(9);
    let (stats2, _) = run_after(Network::new(cfg_4x4().with_vcs(2)), &[clamped]);
    assert_eq!(stats2.delivered_packets, 1);
}

#[test]
fn vc1_config_keeps_whole_link_credit_audit() {
    // The per-VC audit at vcs=1 is exactly the ISSUE 7 whole-link
    // audit: one lane holding all buf_depth credits.
    let mut net = Network::new(cfg_4x4());
    net.schedule_packets(&uniform_specs());
    for _ in 0..500 {
        net.step();
        assert!(net.audit_credits().is_empty());
    }
}

#[test]
fn per_vc_audit_pinpoints_a_leaked_lane() {
    let mut net = Network::new(cfg_4x4().with_vcs(2));
    // Steal one credit from VC 1 of node 0's East output.
    net.routers[0].outputs[Port::East as usize].lanes[1].credits -= 1;
    let v = net.audit_credits();
    assert_eq!(v.len(), 1);
    assert_eq!(v[0].node, NodeId(0));
    assert_eq!(v[0].out, Port::East);
    assert_eq!(v[0].vc, 1);
    assert_eq!(v[0].credits + 1, v[0].buffered + v[0].expected);
    let text = format!(
        "{}",
        StallReport {
            cycle: 0,
            stalled_for: 0,
            cause: StallCause::CreditLeak,
            stuck_packets: vec![],
            credit_audit: v,
        }
    );
    assert!(text.contains("vc 1"), "{text}");
}

#[test]
fn vc_starvation_watchdog_fires_on_a_frozen_channel() {
    // Regression (ISSUE 10 satellite): wedge VC 0 (a frozen flit
    // that never becomes ready) while VC 1 keeps a long stream
    // flowing. Global progress never stops, so only the per-VC
    // watchdog can see the starvation — it must fire with the
    // typed verdict and an intact credit audit.
    let mut net = Network::new(cfg_4x4().with_vcs(2));
    net.set_watchdog(100);
    net.schedule_packets(&[PacketSpec::new(NodeId(5), NodeId(6), 128, 0).on_vc(0)]);
    // Let the flit enter node 5's Local FIFO, then freeze it.
    net.step();
    assert_eq!(net.freeze_packet_for_test(0, u64::MAX), 1);
    // A stream on VC 1, long enough to outlast the window.
    let stream: Vec<PacketSpec> = (0..400u64)
        .map(|k| PacketSpec::new(NodeId(0), NodeId(3), 128 * 2, k).on_vc(1))
        .collect();
    net.schedule_packets(&stream);
    let report = net
        .try_run_to_completion(1_000_000)
        .expect_err("a starved VC must trip the watchdog");
    assert_eq!(report.cause, StallCause::VcStarvation(0));
    assert!(report.credit_audit.is_empty(), "credits must still conserve");
    assert_eq!(report.stalled_for, 0, "the network as a whole kept moving");
    assert!(
        report.stuck_packets.iter().any(|p| p.id == 0),
        "the frozen packet must be reported"
    );
    let text = format!("{report}");
    assert!(text.contains("VcStarvation"), "{text}");
}

#[test]
fn deadlock_freedom_soak_with_adaptive_vcs_and_midrun_cut() {
    // Hotspot pressure on 2 and 4 VCs with a mid-run permanent link
    // failure: the escape channel must keep the run live — watchdog
    // silent, every packet delivered or typed-accounted.
    for vcs in [2u8, 4] {
        let mut net = Network::new(cfg_4x4().with_vcs(vcs));
        net.set_fault_model(FaultModel::new(7).with_link_down(NodeId(5), NodeId(6), 800));
        let mut specs: Vec<PacketSpec> = (1..16u16)
            .map(|i| PacketSpec::new(NodeId(i), NodeId(0), 128 * 16, 0))
            .collect();
        specs.extend((0..100u64).map(|k| {
            PacketSpec::new(
                NodeId((k % 16) as u16),
                NodeId(((k * 5 + 1) % 16) as u16),
                128 * 4,
                k * 3,
            )
        }));
        let specs: Vec<_> = specs.into_iter().filter(|s| s.src != s.dest).collect();
        let n = specs.len() as u64;
        net.schedule_packets(&specs);
        let stats = net
            .try_run_to_completion(500_000)
            .unwrap_or_else(|r| panic!("vcs={vcs} wedged: {r}"));
        assert_eq!(
            stats.delivered_packets + stats.packets_dropped + stats.packets_unreachable,
            n,
            "vcs={vcs}"
        );
        assert_eq!(stats.links_down, 1);
    }
}

// ------------------------------------------------------------------
// ISSUE 10: hierarchical topologies
// ------------------------------------------------------------------

#[test]
fn cmesh_delivers_between_concentrated_endpoints() {
    // 2×2 routers × 4 endpoints each = 16 endpoints. Same-router
    // pairs eject without ever crossing a link.
    let topo = Topo::CMesh(CMesh::new(2, 2, 4));
    let mut specs = Vec::new();
    for i in 0..16u16 {
        for j in 0..16u16 {
            if i != j {
                specs.push(PacketSpec::new(NodeId(i), NodeId(j), 128 * 2, (i as u64) * 3));
            }
        }
    }
    let n = specs.len() as u64;
    let (stats, net) = run_after(Network::new(NetworkConfig::for_topo(topo)), &specs);
    assert_eq!(stats.delivered_packets, n);
    assert!(net.audit_credits().is_empty());
    // Co-located endpoints (same router) share a Local port: a
    // packet between them costs zero link hops.
    let (same_router, _) = run_after(
        Network::new(NetworkConfig::for_topo(topo)),
        &[PacketSpec::new(NodeId(0), NodeId(3), 128 * 4, 0)],
    );
    assert_eq!(same_router.flit_hops, 0);
    assert_eq!(same_router.delivered_flits, 4);
}

#[test]
fn concentrated_injection_shares_the_local_port_fairly() {
    // All 4 endpoints of router 0 inject simultaneously: one flit
    // per router per cycle, so the NI round-robin must interleave
    // them instead of letting endpoint 0 drain first.
    let topo = Topo::CMesh(CMesh::new(2, 2, 4));
    let specs: Vec<PacketSpec> = (0..4u16)
        .map(|i| PacketSpec::new(NodeId(i), NodeId(12), 128 * 4, 0))
        .collect();
    let (stats, net) = run_after(Network::new(NetworkConfig::for_topo(topo)), &specs);
    assert_eq!(stats.delivered_packets, 4);
    let qmax = net.records.iter().map(|r| r.queueing_delay()).max().unwrap();
    let qmin = net.records.iter().map(|r| r.queueing_delay()).min().unwrap();
    assert!(qmin == 0, "someone injects on cycle one");
    assert!(
        qmax >= 3,
        "sharing one Local port must queue the others: max {qmax}"
    );
}

#[test]
fn multipackage_delivers_across_the_stitch() {
    // Two 4×4 packages: cross-package traffic must transit gateway
    // rows; the escape tables are installed from construction (XY
    // is not stitch-safe), and the per-VC audit stays clean.
    let topo = Topo::MultiPackage(MultiPackage::new(2, 4, 4));
    let mut net = Network::new(NetworkConfig::for_topo(topo));
    let specs: Vec<PacketSpec> = (0..16u16)
        .map(|i| PacketSpec::new(NodeId(i), NodeId(16 + ((i * 7) % 16)), 128 * 4, i as u64))
        .collect();
    net.schedule_packets(&specs);
    while !net.drained() {
        assert!(net.now() < 100_000, "multipackage failed to drain");
        net.step();
        let v = net.audit_credits();
        assert!(v.is_empty(), "violation at {}: {:?}", net.now(), v[0]);
    }
    assert_eq!(net.stats().delivered_packets, 16);
    assert!(net.stats().flit_hops >= 16 * 4, "cross-package paths are long");
}

#[test]
fn multipackage_survives_a_gateway_cut_with_vcs() {
    // Kill one of the two row-0↔row-0 stitch links mid-run on a
    // 2-package network with 2 VCs: traffic re-routes over the
    // surviving gateway row, nothing is unreachable.
    let topo = Topo::MultiPackage(MultiPackage::new(2, 4, 4));
    let mp = match topo {
        Topo::MultiPackage(mp) => mp,
        _ => unreachable!(),
    };
    // Row-0 gateway boundary: (pkg 0, x=3, y=0) ↔ (pkg 1, x=0, y=0).
    let a = NodeId(mp.join(0, 3, 0) as u16);
    let b = NodeId(mp.join(1, 0, 0) as u16);
    let mut net = Network::new(NetworkConfig::for_topo(topo).with_vcs(2));
    net.set_fault_model(FaultModel::new(3).with_link_down(a, b, 60));
    let specs: Vec<PacketSpec> = (0..16u16)
        .map(|i| PacketSpec::new(NodeId(i), NodeId(16 + i), 128 * 8, (i as u64) * 2))
        .collect();
    net.schedule_packets(&specs);
    let stats = net
        .try_run_to_completion(200_000)
        .unwrap_or_else(|r| panic!("gateway cut wedged the network: {r}"));
    assert_eq!(stats.links_down, 1);
    assert_eq!(stats.packets_unreachable, 0);
    assert_eq!(
        stats.delivered_packets + stats.packets_dropped,
        16,
        "every packet delivered or typed-dropped"
    );
    assert!(net.audit_credits().is_empty());
}

#[test]
fn bogus_codec_tags_rejected() {
    use crate::packet::CodecTag;
    use lexi_core::codec::CodecKind;
    let tag = |symbols| CodecTag {
        kind: CodecKind::Huffman,
        symbols,
        runtime_book: false,
    };
    let mut net = Network::new(cfg_4x4());
    // More symbols than wire bits: impossible (≥ 1 bit/symbol).
    let bogus = PacketSpec::new(NodeId(0), NodeId(3), 128, 0).tagged(tag(129));
    assert!(net.try_schedule_packets(&[bogus]).is_err());
    // Tag on a zero-size packet.
    let empty = PacketSpec::new(NodeId(0), NodeId(3), 0, 0).tagged(tag(1));
    assert!(net.try_schedule_packets(&[empty]).is_err());
    // Nothing was scheduled; the network stays drained.
    assert!(net.drained());
    // A valid tag passes.
    let ok = PacketSpec::new(NodeId(0), NodeId(3), 128, 0).tagged(tag(128));
    assert!(net.try_schedule_packets(&[ok]).is_ok());
}
