//! Per-virtual-channel router state: input FIFOs, output lanes, and
//! credit partitioning (ISSUE 10).
//!
//! A [`VcRouter`] generalizes the single-VC [`crate::router::Router`]
//! (kept as the legacy reference): each input port holds `vcs` FIFOs,
//! and each output port holds `vcs` *lanes* — per-VC wormhole locks and
//! per-VC credit counters toward the downstream input — plus one
//! flattened round-robin pointer over `(input port × input VC)` shared
//! by the whole output. The link-level buffer budget is unchanged: the
//! `buf_depth` flit slots of each input port are **partitioned** across
//! VCs by [`credit_share`], so per directed link
//! Σ over VCs of (lane credits + downstream FIFO occupancy) ==
//! `buf_depth` — the per-VC refinement of the ISSUE 7 audit invariant.
//!
//! With `vcs = 1` every structure collapses to the legacy router
//! field-for-field: one FIFO per input, one lane per output holding all
//! `buf_depth` credits, and a round-robin pointer over `NUM_PORTS`
//! flat indices — which is how the network pins single-VC runs
//! stat-identical to the pre-refactor implementation.

use crate::packet::Flit;
use crate::topology::NUM_PORTS;
use std::collections::VecDeque;

/// Hard upper bound on VCs per link: lets the switch allocator keep its
/// per-cycle request vector on the stack (no hot-path allocation).
pub const MAX_VCS: u8 = 8;

/// Credits VC `v` starts with: `buf_depth` split as evenly as the
/// integer division allows, remainder to the lower VCs — so the escape
/// channel (VC 0) never gets the short end, and `vcs = 1` keeps the
/// whole depth on its only lane.
pub fn credit_share(buf_depth: u32, vcs: u8, v: u8) -> u32 {
    debug_assert!(v < vcs);
    buf_depth / vcs as u32 + u32::from((v as u32) < buf_depth % vcs as u32)
}

/// One output lane: the wormhole lock + credit counter of a single VC
/// on a directed link.
#[derive(Clone, Debug)]
pub struct VcLane {
    /// `(input port, input VC)` currently holding this lane's wormhole
    /// lock.
    pub locked_to: Option<(usize, u8)>,
    /// Packet whose wormhole holds the lock (identifies the severed
    /// worm when a permanent link failure cuts this output).
    pub locked_packet: Option<u64>,
    /// Credits = free slots of this VC's FIFO at the downstream input.
    pub credits: u32,
}

/// Per-output state: `vcs` lanes plus the shared switch arbiter state.
#[derive(Clone, Debug)]
pub struct VcOutput {
    pub lanes: Vec<VcLane>,
    /// Round-robin pointer over flattened `(input port × input VC)`
    /// indices (`flat = inp * vcs + in_vc`); advanced only when a tail
    /// releases the output, exactly like the legacy per-port pointer.
    pub rr: usize,
    /// Flits forwarded through this output (utilization stat).
    pub forwarded: u64,
}

/// One input port: `vcs` FIFOs sharing the port's `buf_depth` slots.
#[derive(Clone, Debug)]
pub struct VcInput {
    pub fifos: Vec<VecDeque<Flit>>,
}

impl VcInput {
    /// Flits buffered across all VCs of this port.
    pub fn buffered(&self) -> usize {
        self.fifos.iter().map(|f| f.len()).sum()
    }

    /// No flit buffered on any VC?
    pub fn is_empty(&self) -> bool {
        self.fifos.iter().all(|f| f.is_empty())
    }
}

/// A VC-aware 5-port wormhole router: the state the
/// [`crate::input_control`] / [`crate::output_control`] split operates
/// on.
#[derive(Clone, Debug)]
pub struct VcRouter {
    pub inputs: [VcInput; NUM_PORTS],
    pub outputs: [VcOutput; NUM_PORTS],
}

impl VcRouter {
    /// New router with `vcs` virtual channels; each output lane starts
    /// with its [`credit_share`] of the downstream `buf_depth`.
    pub fn new(buf_depth: u32, vcs: u8) -> Self {
        assert!(vcs >= 1, "need at least one virtual channel");
        assert!(vcs <= MAX_VCS, "at most {MAX_VCS} virtual channels");
        VcRouter {
            inputs: std::array::from_fn(|_| VcInput {
                fifos: vec![VecDeque::new(); vcs as usize],
            }),
            outputs: std::array::from_fn(|_| VcOutput {
                lanes: (0..vcs)
                    .map(|v| VcLane {
                        locked_to: None,
                        locked_packet: None,
                        credits: credit_share(buf_depth, vcs, v),
                    })
                    .collect(),
                rr: 0,
                forwarded: 0,
            }),
        }
    }

    /// Number of VCs this router was built with.
    pub fn vcs(&self) -> u8 {
        self.inputs[0].fifos.len() as u8
    }

    /// All input FIFOs empty (router may skip arbitration)?
    pub fn is_idle(&self) -> bool {
        self.inputs.iter().all(|b| b.is_empty())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn credit_share_partitions_exactly() {
        for buf_depth in 1..=8u32 {
            for vcs in 1..=4u8 {
                let total: u32 = (0..vcs).map(|v| credit_share(buf_depth, vcs, v)).sum();
                assert_eq!(total, buf_depth, "depth {buf_depth} vcs {vcs}");
                // Remainder goes to the lower VCs: shares are
                // non-increasing in v and differ by at most one.
                for v in 1..vcs {
                    let (hi, lo) = (
                        credit_share(buf_depth, vcs, v - 1),
                        credit_share(buf_depth, vcs, v),
                    );
                    assert!(hi >= lo && hi - lo <= 1);
                }
            }
        }
        // vcs = 1 keeps the whole depth on the only lane.
        assert_eq!(credit_share(4, 1, 0), 4);
        // The paper point: depth 4 over 2 VCs = 2 + 2; over 4 VCs = 1 each.
        assert_eq!(credit_share(4, 2, 0), 2);
        assert_eq!(credit_share(4, 2, 1), 2);
        assert_eq!(credit_share(4, 4, 3), 1);
        // Odd split favours the escape channel.
        assert_eq!(credit_share(5, 2, 0), 3);
        assert_eq!(credit_share(5, 2, 1), 2);
    }

    #[test]
    fn vc1_router_collapses_to_legacy_shape() {
        let r = VcRouter::new(4, 1);
        assert_eq!(r.vcs(), 1);
        for inp in &r.inputs {
            assert_eq!(inp.fifos.len(), 1);
        }
        for out in &r.outputs {
            assert_eq!(out.lanes.len(), 1);
            assert_eq!(out.lanes[0].credits, 4);
            assert_eq!(out.rr, 0);
        }
        assert!(r.is_idle());
    }
}
