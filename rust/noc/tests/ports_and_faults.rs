//! Behaviour pins for the codec ports, fault handling, watchdog, and
//! permanent-link-failure recovery (ISSUEs 5–7, 9), carried over from
//! the pre-ISSUE-10 in-module suite **with their original expectations
//! intact**: the VC-aware router refactor must reproduce every one of
//! these observable outcomes at `vcs = 1`.

use lexi_core::codec::CodecKind;
use lexi_core::error::Error;
use lexi_noc::fault::{retry_backoff, RETRY_BUDGET};
use lexi_noc::{
    CodecTag, EgressCodecConfig, FaultModel, IngressCodecConfig, Mesh, Network, NetworkConfig,
    NodeId, PacketSpec, RetryConfig, SimStats, StallCause, Topo,
};

fn cfg_4x4() -> NetworkConfig {
    NetworkConfig::for_topo(Topo::Mesh(Mesh::new(4, 4)))
}

fn huff_tag(symbols: u64, runtime_book: bool) -> CodecTag {
    CodecTag {
        kind: CodecKind::Huffman,
        symbols,
        runtime_book,
    }
}

/// Schedule then run (the old in-module `run_to_completion_after`).
fn run_after(net: &mut Network, specs: &[PacketSpec]) -> SimStats {
    net.schedule_packets(specs);
    net.run_to_completion(1_000_000)
}

/// Uniform all-to-all load, 16 flits per packet (240 packets).
fn uniform_16flit_specs() -> Vec<PacketSpec> {
    let mut specs = Vec::new();
    for i in 0..16u16 {
        for j in 0..16u16 {
            if i != j {
                specs.push(PacketSpec::new(NodeId(i), NodeId(j), 128 * 16, (i as u64) * 2));
            }
        }
    }
    specs
}

#[test]
fn wormhole_packets_arrive_contiguously() {
    // With wormhole switching + XY routing, a destination receives each
    // packet's flits in order (seq strictly increasing per packet).
    let mut net = Network::new(cfg_4x4());
    let specs: Vec<PacketSpec> = (0..8u16)
        .map(|i| PacketSpec::new(NodeId(i), NodeId(15), 128 * 8, 0))
        .collect();
    net.schedule_packets(&specs);
    net.run_to_completion(10_000);
    assert_eq!(net.records.len(), 8);
}

#[test]
fn throughput_bounded_by_bisection() {
    // Uniform random cannot exceed ~1 flit/cycle/link utilization.
    let mut net = Network::new(cfg_4x4());
    let mut specs = Vec::new();
    for k in 0..400u64 {
        specs.push(PacketSpec::new(
            NodeId((k * 7 % 16) as u16),
            NodeId((k * 11 % 16) as u16),
            128 * 4,
            k / 8,
        ));
    }
    let specs: Vec<_> = specs.into_iter().filter(|s| s.src != s.dest).collect();
    let links = net.link_count();
    net.schedule_packets(&specs);
    let stats = net.run_to_completion(1_000_000);
    assert!(stats.link_utilization(links) <= 1.0);
}

// ----------------------------------------------------------------------
// ISSUE 5: egress codec ports
// ----------------------------------------------------------------------

#[test]
fn line_rate_egress_matches_codec_blind_ejection() {
    // Paper point (16 lanes): tagged stepping must deliver in the
    // same cycle count as the codec-blind network (offline book ⇒
    // no startup, decoder hidden behind the wire).
    let spec = PacketSpec::new(NodeId(0), NodeId(15), 128 * 64, 0);
    let blind = {
        let mut net = Network::new(cfg_4x4());
        net.schedule_packets(&[spec]);
        net.run_to_completion(10_000)
    };
    let tagged = {
        let mut net = Network::with_egress(cfg_4x4(), EgressCodecConfig::paper_default());
        net.schedule_packets(&[spec.tagged(huff_tag(64 * 8, false))]);
        net.run_to_completion(10_000)
    };
    assert_eq!(blind.cycles, tagged.cycles);
    assert_eq!(tagged.decode_stall_cycles, 0);
    assert_eq!(tagged.delivered_symbols, 64 * 8);
    assert_eq!(tagged.completion_cycle, blind.completion_cycle);
}

#[test]
fn starved_egress_stalls_the_link_and_backpressures() {
    // One decoder lane on a symbol-heavy packet: ejection throttles,
    // stall cycles accrue, and completion stretches to ~the decode
    // makespan instead of the wire time.
    let symbols = 64 * 16u64; // 16 symbols per flit
    let spec =
        PacketSpec::new(NodeId(0), NodeId(15), 128 * 64, 0).tagged(huff_tag(symbols, false));
    let ecfg = EgressCodecConfig::nominal(1, 1.0); // 1.16 cyc/sym at 1 lane
    let cycle_ns = cfg_4x4().cycle_ns();
    let mut net = Network::with_egress(cfg_4x4(), ecfg);
    net.schedule_packets(&[spec]);
    let stats = net.run_to_completion(100_000);
    assert_eq!(stats.delivered_packets, 1);
    assert!(stats.decode_stall_cycles > 0, "no backpressure observed");
    let rec = net.records[0];
    assert_eq!(rec.decode_stall_cycles, stats.decode_stall_cycles);
    // Decode-bound completion ≈ symbols × ns/sym ÷ cycle_ns.
    let decode_cycles = symbols as f64 * ecfg.ns_per_symbol(CodecKind::Huffman) / cycle_ns;
    let done = stats.completion_cycle as f64;
    assert!(
        done >= decode_cycles && done <= decode_cycles * 1.15 + 16.0,
        "completion {done} vs decode bound {decode_cycles}"
    );
}

#[test]
fn runtime_book_startup_charged_on_head_flits() {
    // Identical packets, offline vs runtime book: the runtime one
    // completes later by ~the startup and stalls while the codebook
    // pipeline fills.
    let base = PacketSpec::new(NodeId(0), NodeId(15), 128 * 64, 0);
    let run = |runtime: bool| {
        let mut net = Network::with_egress(cfg_4x4(), EgressCodecConfig::paper_default());
        net.schedule_packets(&[base.tagged(huff_tag(64 * 8, runtime))]);
        net.run_to_completion(100_000)
    };
    let offline = run(false);
    let runtime = run(true);
    let cycle_ns = cfg_4x4().cycle_ns();
    let startup_cycles =
        (EgressCodecConfig::paper_default().startup_ns / cycle_ns).ceil() as u64;
    let delta = runtime.completion_cycle - offline.completion_cycle;
    assert!(
        delta >= startup_cycles - 1 && delta <= startup_cycles + 2,
        "startup delta {delta} vs expected {startup_cycles}"
    );
    assert!(runtime.decode_stall_cycles > 0);
    assert_eq!(offline.decode_stall_cycles, 0);
}

#[test]
fn raw_tagged_packets_never_stall() {
    let spec = PacketSpec::new(NodeId(1), NodeId(14), 128 * 32, 0).tagged(CodecTag {
        kind: CodecKind::Raw,
        symbols: 32 * 16,
        runtime_book: false,
    });
    let mut net = Network::with_egress(cfg_4x4(), EgressCodecConfig::nominal(1, 1.0));
    let stats = run_after(&mut net, &[spec]);
    assert_eq!(stats.decode_stall_cycles, 0);
    assert_eq!(stats.delivered_symbols, 32 * 16);
}

// ----------------------------------------------------------------------
// ISSUE 6: link faults + NACK retransmission
// ----------------------------------------------------------------------

#[test]
fn inert_fault_model_is_stat_identical_to_none() {
    // A fault model attached at all-zero rates must not perturb the
    // simulation in any observable way — this is the zero-BER pin
    // that keeps `sim::xval` and the perf row honest.
    let specs = uniform_16flit_specs();
    let clean = {
        let mut net = Network::new(cfg_4x4());
        run_after(&mut net, &specs)
    };
    let inert = {
        let mut net = Network::with_faults(cfg_4x4(), FaultModel::new(3));
        run_after(&mut net, &specs)
    };
    assert_eq!(clean, inert);
    assert_eq!(inert.flits_corrupted, 0);
    assert_eq!(inert.packet_retries, 0);
}

#[test]
fn seeded_fault_runs_replay_identically() {
    let run = || {
        let mut net =
            Network::with_faults(cfg_4x4(), FaultModel::new(99).with_ber(1e-4).with_dup(0.01));
        run_after(&mut net, &uniform_16flit_specs())
    };
    assert_eq!(run(), run());
}

#[test]
fn ber_run_delivers_every_packet_exactly_once_with_backoff_in_latency() {
    // ISSUE 6 satellite: a BER-injected run must deliver all symbols
    // exactly once (corrupted attempts are NACKed and retransmitted,
    // never recorded), and each retried packet's latency must carry
    // at least its retransmission backoffs.
    let specs = uniform_16flit_specs();
    let n = specs.len() as u64;
    let clean = {
        let mut net = Network::new(cfg_4x4());
        run_after(&mut net, &specs)
    };
    let mut net = Network::with_faults(cfg_4x4(), FaultModel::new(11).with_ber(1e-5));
    let stats = run_after(&mut net, &specs);
    // At this seed/BER the budget is never exhausted: every packet
    // is delivered, each exactly once.
    assert_eq!(stats.delivered_packets + stats.packets_dropped, n);
    assert_eq!(net.records.len() as u64, stats.delivered_packets);
    assert!(stats.flits_corrupted > 0, "seeded BER run injected nothing");
    assert!(stats.packet_retries > 0, "no retransmissions observed");
    assert_eq!(
        stats.link_faults.iter().sum::<u64>(),
        stats.flits_corrupted + stats.flits_dropped + stats.flits_duplicated
    );
    // Retried packets pay backoff + repeat trip in *latency* (their
    // records keep the original head-injection cycle).
    let mut saw_retry = false;
    for r in net.records.iter().filter(|r| r.retries > 0) {
        saw_retry = true;
        let backoffs: u64 = (1..=r.retries).map(retry_backoff).sum();
        assert!(
            r.latency() >= backoffs,
            "retried packet latency {} below its backoff sum {backoffs}",
            r.latency()
        );
    }
    assert!(saw_retry || stats.packets_dropped > 0);
    // Faults can only make the run slower in aggregate.
    assert!(stats.sum_latency >= clean.sum_latency);
}

#[test]
fn lossy_links_retry_at_head_and_still_deliver() {
    // Flit drops are link-level ARQ: the flit retries from the FIFO
    // head, so delivery is lossless and in-order — just slower.
    let spec = PacketSpec::new(NodeId(0), NodeId(15), 128 * 8, 0);
    let clean = {
        let mut net = Network::new(cfg_4x4());
        run_after(&mut net, &[spec])
    };
    let mut net = Network::with_faults(cfg_4x4(), FaultModel::new(5).with_drop(0.3));
    let stats = run_after(&mut net, &[spec]);
    assert_eq!(stats.delivered_packets, 1);
    assert!(stats.flits_dropped > 0, "seeded drop run dropped nothing");
    assert_eq!(stats.packets_dropped, 0);
    assert!(stats.sum_latency >= clean.sum_latency);
}

#[test]
fn retry_budget_exhaustion_reports_drop_without_hanging() {
    // BER = 1.0 corrupts every traversal: the packet is NACKed on
    // all RETRY_BUDGET retransmissions and then reported dropped —
    // run_to_completion drains instead of spinning forever.
    let mut net = Network::with_faults(cfg_4x4(), FaultModel::new(1).with_ber(1.0));
    net.schedule_packets(&[PacketSpec::new(NodeId(0), NodeId(3), 128 * 4, 0)]);
    let stats = net.run_to_completion(10_000);
    assert!(net.drained());
    assert_eq!(stats.delivered_packets, 0);
    assert_eq!(stats.packets_dropped, 1);
    assert_eq!(stats.packet_retries, u64::from(RETRY_BUDGET));
    assert!(net.records.is_empty());
    // The exponential backoffs are cycle-accurate sim time.
    let backoffs: u64 = (1..=RETRY_BUDGET).map(retry_backoff).sum();
    assert!(
        stats.cycles >= backoffs,
        "cycles {} below backoff floor {backoffs}",
        stats.cycles
    );
}

#[test]
fn retry_config_override_moves_the_drop_point_and_backoff_clock() {
    // ISSUE 9 satellite: the budget/backoff are knobs now. A budget
    // of 1 under BER=1.0 drops after a single retransmission; a
    // larger base/cap stretches the deterministic backoff clock.
    let run = |retry: RetryConfig| {
        let mut net =
            Network::with_faults(cfg_4x4(), FaultModel::new(1).with_ber(1.0).with_retry(retry));
        assert_eq!(net.retry_config(), retry);
        net.schedule_packets(&[PacketSpec::new(NodeId(0), NodeId(3), 128 * 4, 0)]);
        net.run_to_completion(10_000)
    };
    let tight = run(RetryConfig {
        budget: 1,
        ..RetryConfig::paper_default()
    });
    assert_eq!(tight.packets_dropped, 1);
    assert_eq!(tight.packet_retries, 1);
    let slow = run(RetryConfig {
        backoff_base: 64,
        backoff_cap: 4096,
        ..RetryConfig::paper_default()
    });
    assert_eq!(slow.packet_retries, u64::from(RETRY_BUDGET));
    let floor: u64 = (1..=RETRY_BUDGET)
        .map(|a| (64u64 << (a - 1).min(32)).min(4096))
        .sum();
    assert!(
        slow.cycles >= floor,
        "cycles {} below stretched backoff floor {floor}",
        slow.cycles
    );
    // And the default path is bit-identical to the pre-knob network.
    let default_cfg = run(RetryConfig::paper_default());
    let mut legacy = Network::with_faults(cfg_4x4(), FaultModel::new(1).with_ber(1.0));
    legacy.schedule_packets(&[PacketSpec::new(NodeId(0), NodeId(3), 128 * 4, 0)]);
    assert_eq!(default_cfg, legacy.run_to_completion(10_000));
}

#[test]
fn duplicated_flits_cost_occupancy_but_deliver_once() {
    let specs = uniform_16flit_specs();
    let n = specs.len() as u64;
    let mut net = Network::with_faults(cfg_4x4(), FaultModel::new(21).with_dup(0.05));
    let stats = run_after(&mut net, &specs);
    assert_eq!(stats.delivered_packets, n);
    assert!(stats.flits_duplicated > 0, "seeded dup run duplicated nothing");
    // Duplicates never create packets or symbols.
    assert_eq!(net.records.len() as u64, n);
    assert_eq!(stats.packets_dropped, 0);
}

#[test]
fn faulty_egress_network_keeps_symbol_accounting_exact() {
    // Corrupted attempts charge speculative decode work but never
    // count delivered symbols; once the retry lands, symbols are
    // counted exactly once.
    let symbols = 64 * 8u64;
    let spec =
        PacketSpec::new(NodeId(0), NodeId(15), 128 * 64, 0).tagged(huff_tag(symbols, false));
    let mut net = Network::with_egress(cfg_4x4(), EgressCodecConfig::paper_default());
    net.set_fault_model(FaultModel::new(17).with_ber(2e-4));
    let stats = run_after(&mut net, &[spec]);
    assert_eq!(stats.delivered_packets + stats.packets_dropped, 1);
    if stats.delivered_packets == 1 {
        assert_eq!(stats.delivered_symbols, symbols);
    } else {
        assert_eq!(stats.delivered_symbols, 0);
    }
}

// ----------------------------------------------------------------------
// ISSUE 7: ingress codec ports
// ----------------------------------------------------------------------

#[test]
fn ingress_line_rate_matches_codec_blind_injection() {
    // Paper point (10 encode lanes): at ≤ ~12 symbols per flit the
    // encoder stays strictly behind the wire, so paced injection is
    // cycle-identical to the codec-blind network.
    let spec = PacketSpec::new(NodeId(0), NodeId(15), 128 * 64, 0);
    let blind = {
        let mut net = Network::new(cfg_4x4());
        run_after(&mut net, &[spec])
    };
    let paced = {
        let mut net = Network::with_ingress(cfg_4x4(), IngressCodecConfig::paper_default());
        run_after(&mut net, &[spec.tagged(huff_tag(64 * 8, false))])
    };
    assert_eq!(blind.cycles, paced.cycles);
    assert_eq!(blind.completion_cycle, paced.completion_cycle);
    assert_eq!(paced.encode_stall_cycles, 0);
    assert_eq!(paced.injections_refused, 0);
}

#[test]
fn starved_ingress_throttles_injection_and_counts_stalls() {
    // One encode lane on a symbol-heavy packet: injection paces to
    // the encoder rate, stall cycles accrue at the NI, and
    // completion stretches to ~the encode makespan.
    let symbols = 64 * 16u64; // 16 symbols per flit
    let spec =
        PacketSpec::new(NodeId(0), NodeId(15), 128 * 64, 0).tagged(huff_tag(symbols, false));
    let icfg = IngressCodecConfig::nominal(1, 1.0); // 1 ns/symbol
    let cycle_ns = cfg_4x4().cycle_ns();
    let mut net = Network::with_ingress(cfg_4x4(), icfg);
    let stats = run_after(&mut net, &[spec]);
    assert_eq!(stats.delivered_packets, 1);
    assert!(stats.encode_stall_cycles > 0, "no encode backpressure observed");
    let rec = net.records[0];
    assert_eq!(rec.encode_stall_cycles, stats.encode_stall_cycles);
    // Encode-bound completion ≈ symbols × ns/sym ÷ cycle_ns (the
    // tail leaves the encoder a flit-cost early, hence the slack).
    let encode_cycles = symbols as f64 * icfg.ns_per_symbol(CodecKind::Huffman) / cycle_ns;
    let done = stats.completion_cycle as f64;
    assert!(
        done >= encode_cycles - 16.0 && done <= encode_cycles * 1.15 + 16.0,
        "completion {done} vs encode bound {encode_cycles}"
    );
}

#[test]
fn ingress_startup_charged_once_on_runtime_head() {
    // Identical packets, offline vs runtime codebook: the runtime
    // one completes later by ~the compressor startup, charged once
    // on the head flit; followers stall at the NI while it drains.
    let base = PacketSpec::new(NodeId(0), NodeId(15), 128 * 64, 0);
    let run = |runtime: bool| {
        let mut net = Network::with_ingress(cfg_4x4(), IngressCodecConfig::paper_default());
        run_after(&mut net, &[base.tagged(huff_tag(64 * 8, runtime))])
    };
    let offline = run(false);
    let runtime = run(true);
    let cycle_ns = cfg_4x4().cycle_ns();
    let startup_cycles =
        (IngressCodecConfig::paper_default().startup_ns / cycle_ns).ceil() as u64;
    let delta = runtime.completion_cycle - offline.completion_cycle;
    assert!(
        delta >= startup_cycles - 1 && delta <= startup_cycles + 2,
        "startup delta {delta} vs expected {startup_cycles}"
    );
    assert!(runtime.encode_stall_cycles > 0);
    assert_eq!(offline.encode_stall_cycles, 0);
}

#[test]
fn bounded_ni_admission_defers_and_counts() {
    // More same-source arrivals than the NI bound: the excess is
    // deferred cycle by cycle (refusals counted), yet every packet
    // is eventually delivered — bounded memory, no loss.
    let icfg = IngressCodecConfig::nominal(1, 1.0);
    assert_eq!(icfg.max_queue, lexi_noc::ingress::DEFAULT_MAX_QUEUE);
    let specs: Vec<PacketSpec> = (0..12)
        .map(|_| {
            PacketSpec::new(NodeId(0), NodeId(15), 128 * 8, 0).tagged(huff_tag(8 * 16, false))
        })
        .collect();
    let mut net = Network::with_ingress(cfg_4x4(), icfg);
    let stats = run_after(&mut net, &specs);
    assert_eq!(stats.delivered_packets, 12);
    assert!(stats.injections_refused > 0, "bound never engaged");
}

#[test]
fn try_inject_backpressures_with_typed_refusal() {
    // Closed-loop generator: admission beyond the NI bound is a
    // typed IngressSaturated refusal, and room reopens as the
    // encoder drains — backpressure reaches the caller, not an
    // unbounded queue.
    let mut icfg = IngressCodecConfig::nominal(1, 1.0);
    icfg.max_queue = 2;
    let mut net = Network::with_ingress(cfg_4x4(), icfg);
    let spec = PacketSpec::new(NodeId(0), NodeId(15), 128 * 8, 0).tagged(huff_tag(8 * 16, false));
    assert!(net.try_inject(spec).is_ok());
    assert!(net.try_inject(spec).is_ok());
    match net.try_inject(spec) {
        Err(Error::IngressSaturated { node: 0, depth: 2 }) => {}
        other => panic!("expected typed saturation, got {other:?}"),
    }
    assert_eq!(net.stats().injections_refused, 1);
    // Drain enough for one packet to clear the NI, then retry.
    for _ in 0..1500 {
        net.step();
        if net.try_inject(spec).is_ok() {
            break;
        }
    }
    let stats = net.run_to_completion(100_000);
    assert_eq!(stats.delivered_packets, 3);
}

// ----------------------------------------------------------------------
// ISSUE 7: stall/deadlock watchdog
// ----------------------------------------------------------------------

#[test]
fn zero_rate_egress_terminates_with_stall_report() {
    // Regression: a decoder that never drains used to spin
    // run_to_completion to the horizon. The watchdog must terminate
    // promptly with a typed report naming the stuck packet and the
    // zero-rate port as the suspected cause.
    let mut ecfg = EgressCodecConfig::nominal(16, 1.0);
    ecfg.set_rate(CodecKind::Huffman, 1e12);
    let mut net = Network::with_egress(cfg_4x4(), ecfg);
    net.set_watchdog(200);
    net.schedule_packets(&[PacketSpec::new(NodeId(0), NodeId(3), 128 * 8, 0)
        .tagged(huff_tag(64, false))]);
    let report = net
        .try_run_to_completion(1_000_000)
        .expect_err("a wedged run must not drain");
    assert_eq!(report.cause, StallCause::ZeroRatePort);
    assert_eq!(report.stuck_packets.len(), 1);
    assert_eq!(report.stuck_packets[0].dest, NodeId(3));
    assert!(report.credit_audit.is_empty(), "credits must still conserve");
    assert!(report.stalled_for >= 200);
    assert!(net.now() < 10_000, "watchdog fired late: {}", net.now());
    // The report renders human-readable.
    let text = format!("{report}");
    assert!(text.contains("ZeroRatePort"), "{text}");
}

#[test]
fn drop_every_flit_terminates_with_dead_link_verdict() {
    // drop_prob = 1.0 is a dead link in transient clothing: no flit
    // ever traverses, no NACK ever fires (nothing reaches egress),
    // and pre-watchdog the step loop span forever.
    let mut net = Network::with_faults(cfg_4x4(), FaultModel::new(4).with_drop(1.0));
    net.set_watchdog(300);
    net.schedule_packets(&[PacketSpec::new(NodeId(0), NodeId(3), 128 * 4, 0)]);
    let report = net
        .try_run_to_completion(1_000_000)
        .expect_err("a dead link must trip the watchdog");
    assert_eq!(report.cause, StallCause::DeadLink);
    assert!(!report.stuck_packets.is_empty());
    assert!(report.credit_audit.is_empty());
}

#[test]
fn watchdog_never_fires_on_healthy_sparse_traffic() {
    // Arrival gaps far beyond the watchdog window: future-due
    // schedule entries are provable progress, so a healthy mesh
    // must complete — quiet spells are not stalls.
    let mut net = Network::new(cfg_4x4());
    net.set_watchdog(64);
    let specs: Vec<PacketSpec> = (0..40u64)
        .map(|k| {
            PacketSpec::new(
                NodeId((k * 3 % 16) as u16),
                NodeId((k * 5 % 16) as u16),
                128 * 4,
                k * 200,
            )
        })
        .filter(|s| s.src != s.dest)
        .collect();
    let n = specs.len() as u64;
    net.schedule_packets(&specs);
    let stats = net
        .try_run_to_completion(100_000)
        .expect("healthy mesh must never trip the watchdog");
    assert_eq!(stats.delivered_packets, n);
}

#[test]
fn credit_conservation_soak_under_faults_and_link_downs() {
    // Property soak (ISSUE 7 satellite): ≥ 10k cycles of seeded
    // random traffic × transient faults × two mid-run permanent
    // link failures — the per-link credit invariant must hold on
    // *every* cycle, and packet accounting must stay exact.
    let mut net = Network::new(cfg_4x4());
    net.set_fault_model(
        FaultModel::new(77)
            .with_ber(1e-4)
            .with_drop(0.02)
            .with_dup(0.01)
            .with_link_down(NodeId(5), NodeId(6), 3_000)
            .with_link_down(NodeId(9), NodeId(10), 7_000),
    );
    let mut specs = Vec::new();
    for k in 0..500u64 {
        let (s, d) = ((k * 7 % 16) as u16, ((k * 11 + 3) % 16) as u16);
        if s != d {
            specs.push(PacketSpec::new(NodeId(s), NodeId(d), 128 * 8, k * 25));
        }
    }
    let n = specs.len() as u64;
    net.schedule_packets(&specs);
    let mut cycles = 0u64;
    while !net.drained() {
        assert!(net.now() < 200_000, "soak failed to drain");
        net.step();
        cycles += 1;
        let v = net.audit_credits();
        assert!(
            v.is_empty(),
            "credit violation at cycle {}: {:?}",
            net.now(),
            v[0]
        );
    }
    assert!(cycles >= 10_000, "soak too short: {cycles} cycles");
    let stats = net.stats();
    assert_eq!(stats.links_down, 2);
    // A 4x4 mesh stays connected after these two cuts: every packet
    // is delivered or (budget-exhausted) reported dropped.
    assert_eq!(stats.packets_unreachable, 0);
    assert_eq!(stats.delivered_packets + stats.packets_dropped, n);
}

// ----------------------------------------------------------------------
// ISSUE 7: permanent link failures + adaptive recovery
// ----------------------------------------------------------------------

#[test]
fn link_down_truncates_worm_and_redelivers_via_reroute() {
    // Kill the 1↔2 link while a 16-flit worm 0→3 is strung across
    // it: the worm is truncated (credits returned), NACK-retried,
    // and the retry is delivered over the escape route.
    let mut net = Network::new(cfg_4x4());
    net.set_fault_model(FaultModel::new(1).with_link_down(NodeId(1), NodeId(2), 6));
    net.schedule_packets(&[PacketSpec::new(NodeId(0), NodeId(3), 128 * 16, 0)]);
    let stats = net.run_to_completion(10_000);
    assert_eq!(stats.delivered_packets, 1);
    assert_eq!(stats.links_down, 1);
    assert_eq!(stats.packets_truncated, 1);
    assert!(stats.packet_retries >= 1);
    assert_eq!(stats.packets_unreachable, 0);
    let rec = net.records[0];
    assert!(rec.retries >= 1, "delivery must be a logged retransmission");
    assert!(net.audit_credits().is_empty());
}

#[test]
fn link_down_before_traffic_reroutes_without_truncation() {
    // The link dies before injection: no worm to cut — the packet
    // simply routes around the failure (longer than the 3-hop XY
    // path the cut removed).
    let mut net = Network::new(cfg_4x4());
    net.set_fault_model(FaultModel::new(1).with_link_down(NodeId(1), NodeId(2), 0));
    net.schedule_packets(&[PacketSpec::new(NodeId(0), NodeId(3), 128 * 16, 10)]);
    let stats = net.run_to_completion(10_000);
    assert_eq!(stats.delivered_packets, 1);
    assert_eq!(stats.packets_truncated, 0);
    assert_eq!(stats.packet_retries, 0);
    assert!(
        stats.flit_hops > 16 * 3,
        "escape path must be longer than the severed XY path: {} hops",
        stats.flit_hops
    );
}

#[test]
fn severed_destination_is_typed_unreachable() {
    // Cut both links of corner node 0 (3x3): packets bound there
    // are reported unreachable — and the run still drains; packets
    // between surviving nodes still deliver.
    let cfg = NetworkConfig::for_topo(Topo::Mesh(Mesh::new(3, 3)));
    let mut net = Network::new(cfg);
    net.set_fault_model(
        FaultModel::new(1)
            .with_link_down(NodeId(0), NodeId(1), 0)
            .with_link_down(NodeId(0), NodeId(3), 0),
    );
    net.schedule_packets(&[
        PacketSpec::new(NodeId(8), NodeId(0), 128 * 4, 5),
        PacketSpec::new(NodeId(8), NodeId(4), 128 * 4, 5),
    ]);
    let stats = net.run_to_completion(10_000);
    assert!(net.drained());
    assert_eq!(stats.delivered_packets, 1);
    assert_eq!(stats.packets_unreachable, 1);
    assert_eq!(net.unreachable_packets().len(), 1);
    assert_eq!(net.unreachable_packets()[0].dest, NodeId(0));
    // Scheduling into the severed island is now a typed refusal...
    let err = net
        .try_schedule_packets(&[PacketSpec::new(NodeId(8), NodeId(0), 128, 100)])
        .expect_err("severed dest must be refused");
    assert!(matches!(err, Error::Unreachable { src: 8, dest: 0 }), "{err:?}");
    // ...and so is closed-loop injection.
    assert!(matches!(
        net.try_inject(PacketSpec::new(NodeId(3), NodeId(0), 128, 0)),
        Err(Error::Unreachable { .. })
    ));
}

#[test]
fn duplex_codec_ports_compose_with_exact_accounting() {
    // Ingress AND egress ports starved (1 lane each): both stall
    // kinds are counted, and symbol accounting stays exact.
    let symbols = 64 * 16u64;
    let spec =
        PacketSpec::new(NodeId(0), NodeId(15), 128 * 64, 0).tagged(huff_tag(symbols, true));
    let mut net = Network::with_egress(cfg_4x4(), EgressCodecConfig::nominal(1, 1.0));
    net.set_ingress_config(IngressCodecConfig::nominal(1, 1.0));
    let stats = run_after(&mut net, &[spec]);
    assert_eq!(stats.delivered_packets, 1);
    assert!(stats.encode_stall_cycles > 0);
    assert!(stats.decode_stall_cycles > 0);
    assert_eq!(stats.delivered_symbols, symbols);
    let rec = net.records[0];
    assert_eq!(rec.encode_stall_cycles, stats.encode_stall_cycles);
    assert_eq!(rec.decode_stall_cycles, stats.decode_stall_cycles);
}
