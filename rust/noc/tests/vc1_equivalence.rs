//! ISSUE 10 acceptance: a `vcs = 1` [`Network`] must be **stat-identical,
//! field for field**, to the pre-refactor single-VC router — across random
//! traffic, transient faults, and mid-run permanent link failures.
//!
//! The pre-refactor network no longer exists as a type, so this file
//! carries it as a test-local *oracle*: a line-for-line port of the old
//! `network.rs` step loop (codec ports omitted — the traffic here is
//! untagged, so neither side touches them) driving the legacy
//! [`Router`], which the crate keeps precisely as this test's executable
//! specification. Oracle and [`Network`] are stepped over identical
//! seeded inputs and their [`SimStats`] compared with `==` — every
//! field, including cycle counts, latency sums, retry/truncation
//! counters, and the per-router fault vector. Any behavioural drift in
//! the refactored input/output-control path shows up here as a
//! first-class diff, not a vague regression.

use lexi_core::prng::Rng;
use lexi_core::proptest::check;
use lexi_noc::fault::LinkDown;
use lexi_noc::reroute::LinkState;
use lexi_noc::router::Router;
use lexi_noc::topology::NUM_PORTS;
use lexi_noc::{
    EscapeRoutes, FaultModel, Flit, FlitKind, Mesh, Network, NetworkConfig, NodeId, PacketRecord,
    PacketSpec, Port, RetryConfig, SimStats, Topo,
};
use std::collections::{HashMap, VecDeque};

// ======================================================================
// The oracle: the pre-ISSUE-10 network, ported verbatim (minus codec
// ports) on top of the legacy `Router`.
// ======================================================================

#[derive(Clone, Copy, Debug)]
struct Pending {
    id: u64,
    spec: PacketSpec,
    total_flits: u32,
    emitted: u32,
}

#[derive(Clone, Copy, Debug)]
struct Meta {
    spec: PacketSpec,
    total_flits: u32,
    head_inject: Option<u64>,
    corrupted: bool,
    attempt: u32,
    first_inject: Option<u64>,
}

#[derive(Clone, Copy, Debug)]
struct RetryEntry {
    spec: PacketSpec,
    due: u64,
    attempt: u32,
    first_inject: u64,
}

struct Oracle {
    mesh: Mesh,
    flit_bits: u32,
    buf_depth: u32,
    routers: Vec<Router>,
    ni_queues: Vec<VecDeque<Pending>>,
    schedule: Vec<PacketSpec>,
    meta: HashMap<u64, Meta>,
    fault: Option<FaultModel>,
    retry_queue: Vec<RetryEntry>,
    retry: RetryConfig,
    pending_link_downs: Vec<LinkDown>,
    down: LinkState,
    escape: Option<EscapeRoutes>,
    unreachable: Vec<PacketSpec>,
    records: Vec<PacketRecord>,
    now: u64,
    next_id: u64,
    stats: SimStats,
}

impl Oracle {
    fn new(mesh: Mesh, flit_bits: u32, buf_depth: u32) -> Self {
        let n = mesh.len();
        Oracle {
            mesh,
            flit_bits,
            buf_depth,
            routers: (0..n).map(|_| Router::new(buf_depth)).collect(),
            ni_queues: vec![VecDeque::new(); n],
            schedule: Vec::new(),
            meta: HashMap::new(),
            fault: None,
            retry_queue: Vec::new(),
            retry: RetryConfig::paper_default(),
            pending_link_downs: Vec::new(),
            down: vec![[false; NUM_PORTS]; n],
            escape: None,
            unreachable: Vec::new(),
            records: Vec::new(),
            now: 0,
            next_id: 0,
            stats: SimStats {
                link_faults: vec![0; n],
                ..SimStats::default()
            },
        }
    }

    fn set_fault_model(&mut self, fault: FaultModel) {
        self.pending_link_downs = fault.link_downs().to_vec();
        self.retry = fault.retry();
        self.fault = Some(fault);
    }

    fn adjacent_port(&self, a: NodeId, b: NodeId) -> Option<Port> {
        Port::ALL[1..]
            .iter()
            .copied()
            .find(|&p| self.mesh.neighbour(a, p) == Some(b))
    }

    fn schedule_packets(&mut self, specs: &[PacketSpec]) {
        self.schedule.extend_from_slice(specs);
        self.schedule
            .sort_by_key(|s| std::cmp::Reverse(s.inject_at));
    }

    fn activate(&mut self, spec: PacketSpec, attempt: u32, first_inject: Option<u64>) {
        let id = self.next_id;
        self.next_id += 1;
        let total = spec.flits(self.flit_bits);
        self.meta.insert(
            id,
            Meta {
                spec,
                total_flits: total,
                head_inject: None,
                corrupted: false,
                attempt,
                first_inject,
            },
        );
        self.ni_queues[spec.src.0 as usize].push_back(Pending {
            id,
            spec,
            total_flits: total,
            emitted: 0,
        });
    }

    fn drained(&self) -> bool {
        self.schedule.is_empty() && self.meta.is_empty() && self.retry_queue.is_empty()
    }

    fn step(&mut self) {
        let mesh = self.mesh;
        let faults_on = self.fault.as_ref().is_some_and(|f| f.enabled());

        // --- 0. scheduled permanent link failures ---
        if !self.pending_link_downs.is_empty() {
            while let Some(&e) = self.pending_link_downs.first() {
                if e.at > self.now {
                    break;
                }
                self.pending_link_downs.remove(0);
                self.apply_link_down(e.a, e.b);
            }
        }

        // --- 1. activation (unbounded NI — no ingress config) ---
        while let Some(last) = self.schedule.last() {
            if last.inject_at > self.now {
                break;
            }
            let spec = self.schedule.pop().expect("non-empty");
            self.activate(spec, 0, None);
        }

        // --- 1b. retransmissions whose backoff has elapsed ---
        if !self.retry_queue.is_empty() {
            let mut i = 0;
            while i < self.retry_queue.len() {
                if self.retry_queue[i].due > self.now {
                    i += 1;
                    continue;
                }
                let e = self.retry_queue.swap_remove(i);
                self.activate(e.spec, e.attempt, Some(e.first_inject));
            }
        }

        // --- 2. injection: one flit per node per cycle ---
        for (node, q) in self.ni_queues.iter_mut().enumerate() {
            if let Some(p) = q.front_mut() {
                if (self.routers[node].inputs[Port::Local as usize].fifo.len() as u32)
                    < self.buf_depth
                {
                    let seq = p.emitted;
                    let kind = match (seq, p.total_flits) {
                        (0, 1) => FlitKind::Single,
                        (0, _) => FlitKind::Head,
                        (s, t) if s + 1 == t => FlitKind::Tail,
                        _ => FlitKind::Body,
                    };
                    if seq == 0 {
                        self.meta
                            .get_mut(&p.id)
                            .expect("activated packet has meta")
                            .head_inject = Some(self.now);
                    }
                    self.routers[node].inputs[Port::Local as usize]
                        .fifo
                        .push_back(Flit {
                            packet_id: p.id,
                            kind,
                            src: p.spec.src,
                            dest: p.spec.dest,
                            seq,
                            vc: 0,
                            ready_at: self.now + 1,
                            codec: p.spec.codec,
                        });
                    p.emitted += 1;
                    if p.emitted == p.total_flits {
                        q.pop_front();
                    }
                }
            }
        }

        // --- 3. forwarding / ejection ---
        for node in 0..self.routers.len() {
            if self.routers[node].inputs.iter().all(|b| b.fifo.is_empty()) {
                continue;
            }
            let at = NodeId(node as u16);
            let grants = match self.escape.as_ref() {
                None => self.routers[node].arbitrate_all(self.now, |_, f| {
                    mesh.route_xy(at, f.dest)
                }),
                Some(esc) => self.routers[node].arbitrate_all(self.now, |inp, f| {
                    esc.next_hop(node, inp, f.dest.0 as usize)
                        .expect("unroutable flits are truncated at link-down time")
                }),
            };
            for &out in &Port::ALL {
                let Some(inp) = grants[out as usize] else { continue };

                if out == Port::Local {
                    let flit = self.routers[node].inputs[inp]
                        .fifo
                        .pop_front()
                        .expect("arbitrated input non-empty");
                    self.credit_return(at, inp);
                    self.update_lock(node, out, inp, &flit);
                    self.stats.delivered_flits += 1;
                    if flit.is_tail() {
                        let m = self.meta.remove(&flit.packet_id).expect("meta");
                        let inject_cycle = m
                            .first_inject
                            .or(m.head_inject)
                            .expect("tail ejected before head injected");
                        if m.corrupted {
                            if m.attempt < self.retry.budget {
                                let next = m.attempt + 1;
                                self.stats.packet_retries += 1;
                                self.retry_queue.push(RetryEntry {
                                    spec: m.spec,
                                    due: self.now + 1 + self.retry.backoff(next),
                                    attempt: next,
                                    first_inject: inject_cycle,
                                });
                            } else {
                                self.stats.packets_dropped += 1;
                            }
                            continue;
                        }
                        let eject_cycle = self.now + 1;
                        let rec = PacketRecord {
                            spec: m.spec,
                            inject_cycle,
                            eject_cycle,
                            flits: m.total_flits,
                            decode_stall_cycles: 0,
                            encode_stall_cycles: 0,
                            retries: m.attempt,
                        };
                        self.stats.delivered_packets += 1;
                        self.stats.sum_latency += rec.latency();
                        self.stats.max_latency = self.stats.max_latency.max(rec.latency());
                        self.stats.sum_queueing += rec.queueing_delay();
                        if let Some(tag) = m.spec.codec {
                            self.stats.delivered_symbols += tag.symbols;
                        }
                        self.stats.completion_cycle =
                            self.stats.completion_cycle.max(eject_cycle);
                        self.records.push(rec);
                    }
                    continue;
                }

                if self.routers[node].outputs[out as usize].credits == 0 {
                    continue;
                }
                let Some(nb) = mesh.neighbour(at, out) else {
                    unreachable!("routing never exits the mesh");
                };
                if faults_on && self.fault.as_mut().expect("gated").drops() {
                    self.stats.flits_dropped += 1;
                    self.stats.link_faults[node] += 1;
                    continue;
                }
                let mut flit = self.routers[node].inputs[inp]
                    .fifo
                    .pop_front()
                    .expect("arbitrated input non-empty");
                self.credit_return(at, inp);
                self.update_lock(node, out, inp, &flit);
                self.routers[node].outputs[out as usize].credits -= 1;
                self.routers[node].outputs[out as usize].forwarded += 1;
                self.stats.flit_hops += 1;
                flit.ready_at = self.now + 1;
                if faults_on {
                    let flit_bits = self.flit_bits;
                    if self.fault.as_mut().expect("gated").corrupts(flit_bits) {
                        self.stats.flits_corrupted += 1;
                        self.stats.link_faults[node] += 1;
                        self.meta
                            .get_mut(&flit.packet_id)
                            .expect("in-flight packet has meta")
                            .corrupted = true;
                    }
                    if self.fault.as_mut().expect("gated").duplicates() {
                        self.stats.flits_duplicated += 1;
                        self.stats.link_faults[node] += 1;
                        flit.ready_at = self.now + 2;
                    }
                }
                self.routers[nb.0 as usize].inputs[out.opposite() as usize]
                    .fifo
                    .push_back(flit);
            }
        }

        self.now += 1;
        self.stats.cycles = self.now;
    }

    fn apply_link_down(&mut self, a: NodeId, b: NodeId) {
        let pab = self.adjacent_port(a, b).expect("validated adjacency");
        let pba = pab.opposite();
        if self.down[a.0 as usize][pab as usize] {
            return;
        }
        self.down[a.0 as usize][pab as usize] = true;
        self.down[b.0 as usize][pba as usize] = true;
        self.stats.links_down += 1;

        self.escape = Some(EscapeRoutes::compute(Topo::Mesh(self.mesh), &self.down));

        let (victims, purge, sched_gone, retry_gone) = {
            let esc = self.escape.as_ref().expect("just installed");
            let mut victims: Vec<u64> = Vec::new();
            for (u, pout) in [(a, pab), (b, pba)] {
                if let Some(pid) =
                    self.routers[u.0 as usize].outputs[pout as usize].locked_packet
                {
                    victims.push(pid);
                }
            }
            for (node, r) in self.routers.iter().enumerate() {
                for (inp, buf) in r.inputs.iter().enumerate() {
                    for f in &buf.fifo {
                        if esc.next_hop(node, inp, f.dest.0 as usize).is_none() {
                            victims.push(f.packet_id);
                        }
                    }
                }
                for (out, o) in r.outputs.iter().enumerate() {
                    let (Some(pid), Some(inp)) = (o.locked_packet, o.locked_to) else {
                        continue;
                    };
                    let Some(m) = self.meta.get(&pid) else { continue };
                    if esc.next_hop(node, inp, m.spec.dest.0 as usize) != Some(Port::ALL[out]) {
                        victims.push(pid);
                    }
                }
            }
            victims.sort_unstable();
            victims.dedup();

            let mut purge: Vec<u64> = Vec::new();
            for q in &self.ni_queues {
                for p in q {
                    if !esc.reachable(p.spec.src, p.spec.dest) {
                        purge.push(p.id);
                    }
                }
            }
            let sched = std::mem::take(&mut self.schedule);
            let (sched_keep, sched_gone): (Vec<_>, Vec<_>) = sched
                .into_iter()
                .partition(|s| esc.reachable(s.src, s.dest));
            self.schedule = sched_keep;
            let retries = std::mem::take(&mut self.retry_queue);
            let (retry_keep, retry_gone): (Vec<_>, Vec<_>) = retries
                .into_iter()
                .partition(|e| esc.reachable(e.spec.src, e.spec.dest));
            self.retry_queue = retry_keep;
            (victims, purge, sched_gone, retry_gone)
        };

        for s in sched_gone {
            self.stats.packets_unreachable += 1;
            self.unreachable.push(s);
        }
        for e in retry_gone {
            self.stats.packets_unreachable += 1;
            self.unreachable.push(e.spec);
        }
        for pid in victims.into_iter().chain(purge) {
            self.truncate_packet(pid);
        }
    }

    fn truncate_packet(&mut self, pid: u64) {
        let Some(m) = self.meta.remove(&pid) else {
            return;
        };
        for node in 0..self.routers.len() {
            let at = NodeId(node as u16);
            for inp in 0..NUM_PORTS {
                let removed = {
                    let fifo = &mut self.routers[node].inputs[inp].fifo;
                    let before = fifo.len();
                    fifo.retain(|f| f.packet_id != pid);
                    before - fifo.len()
                };
                for _ in 0..removed {
                    self.credit_return(at, inp);
                }
            }
            for o in self.routers[node].outputs.iter_mut() {
                if o.locked_packet == Some(pid) {
                    o.locked_to = None;
                    o.locked_packet = None;
                }
            }
        }
        self.ni_queues[m.spec.src.0 as usize].retain(|p| p.id != pid);
        if m.head_inject.is_some() {
            self.stats.packets_truncated += 1;
        }
        let reachable = self
            .escape
            .as_ref()
            .map_or(true, |e| e.reachable(m.spec.src, m.spec.dest));
        if !reachable {
            self.stats.packets_unreachable += 1;
            self.unreachable.push(m.spec);
        } else if m.attempt < self.retry.budget {
            let next = m.attempt + 1;
            self.stats.packet_retries += 1;
            self.retry_queue.push(RetryEntry {
                spec: m.spec,
                due: self.now + 1 + self.retry.backoff(next),
                attempt: next,
                first_inject: m.first_inject.or(m.head_inject).unwrap_or(self.now),
            });
        } else {
            self.stats.packets_dropped += 1;
        }
    }

    fn credit_return(&mut self, at: NodeId, inp: usize) {
        if inp == Port::Local as usize {
            return;
        }
        let in_port = Port::ALL[inp];
        if let Some(up) = self.mesh.neighbour(at, in_port) {
            let up_out = in_port.opposite() as usize;
            self.routers[up.0 as usize].outputs[up_out].credits += 1;
        }
    }

    fn update_lock(&mut self, node: usize, out: Port, inp: usize, flit: &Flit) {
        let o = &mut self.routers[node].outputs[out as usize];
        if flit.is_tail() {
            o.locked_to = None;
            o.locked_packet = None;
            o.rr = (inp + 1) % NUM_PORTS;
        } else {
            o.locked_to = Some(inp);
            o.locked_packet = Some(flit.packet_id);
        }
    }

    fn run_to_completion(&mut self, max_cycles: u64) -> SimStats {
        while !self.drained() {
            assert!(
                self.now < max_cycles,
                "oracle failed to drain by cycle {max_cycles}"
            );
            self.step();
        }
        self.stats.clone()
    }
}

// ======================================================================
// Harness
// ======================================================================

fn mesh_4x4() -> Mesh {
    Mesh::new(4, 4)
}

fn vcs1_cfg() -> NetworkConfig {
    NetworkConfig::for_topo(Topo::Mesh(mesh_4x4()))
}

/// Run both implementations over the same inputs and demand **exact**
/// agreement: `SimStats` by `==` (every field), delivery records as
/// sorted multisets, and the unreachable-spec lists by length.
fn assert_stat_identical(specs: &[PacketSpec], fault: Option<&dyn Fn() -> FaultModel>) {
    let cfg = vcs1_cfg();
    let mut oracle = Oracle::new(mesh_4x4(), cfg.flit_bits, cfg.buf_depth);
    let mut net = Network::new(cfg);
    if let Some(make) = fault {
        oracle.set_fault_model(make());
        net.set_fault_model(make());
    }
    oracle.schedule_packets(specs);
    net.schedule_packets(specs);
    let want = oracle.run_to_completion(1_000_000);
    let got = net.run_to_completion(1_000_000);
    assert_eq!(want, got, "vcs=1 SimStats diverged from the legacy router");
    let key = |r: &PacketRecord| {
        (
            r.spec.src.0,
            r.spec.dest.0,
            r.spec.inject_at,
            r.inject_cycle,
            r.eject_cycle,
            r.flits,
            r.retries,
        )
    };
    let mut a: Vec<_> = oracle.records.iter().map(key).collect();
    let mut b: Vec<_> = net.records.iter().map(key).collect();
    a.sort_unstable();
    b.sort_unstable();
    assert_eq!(a, b, "per-packet records diverged");
    assert_eq!(oracle.unreachable.len(), net.unreachable_packets().len());
    assert!(net.audit_credits().is_empty(), "per-VC credit audit dirty");
}

fn random_specs(rng: &mut Rng, count: usize) -> Vec<PacketSpec> {
    let mut specs = Vec::with_capacity(count);
    for _ in 0..count {
        let src = NodeId(rng.below(16) as u16);
        let mut dest = NodeId(rng.below(16) as u16);
        while dest == src {
            dest = NodeId(rng.below(16) as u16);
        }
        let flits = 1 + rng.below(12);
        let at = rng.below(400);
        specs.push(PacketSpec::new(src, dest, 128 * flits, at));
    }
    specs
}

// ======================================================================
// Tests
// ======================================================================

#[test]
fn prop_vc1_clean_runs_match_legacy_router_exactly() {
    // Random traffic mixes — sparse to saturating — on a healthy mesh:
    // the refactored router at vcs=1 must reproduce the legacy stats
    // bit for bit (cycles, latency sums, hop counts, completion).
    check("vcs=1 ≡ legacy (clean)", 12, |g| {
        let count = g.usize(1..160);
        let specs = random_specs(g.rng(), count);
        assert_stat_identical(&specs, None);
    });
}

#[test]
fn prop_vc1_faulty_runs_match_legacy_router_exactly() {
    // Transient faults (BER corruption, drops, duplicates) exercise the
    // NACK-retry machinery and the per-router fault vector; the seeded
    // draw sequences must line up event for event.
    check("vcs=1 ≡ legacy (faults)", 8, |g| {
        let count = g.usize(1..100);
        let specs = random_specs(g.rng(), count);
        let seed = g.u64(0..1 << 48);
        let make = move || {
            FaultModel::new(seed)
                .with_ber(1e-4)
                .with_drop(0.02)
                .with_dup(0.01)
        };
        assert_stat_identical(&specs, Some(&make));
    });
}

#[test]
fn prop_vc1_link_down_recovery_matches_legacy_router_exactly() {
    // Mid-run permanent link failures: wormhole truncation, credit
    // return, escape-table rerouting, and retry accounting all ride the
    // refactored path — and must still be indistinguishable at vcs=1.
    // Interior cuts keep the 4x4 connected, so nothing goes unreachable
    // and every divergence is a hard stat diff.
    let cuts: [(u16, u16, u64); 4] = [(5, 6, 40), (9, 10, 120), (6, 10, 25), (1, 2, 300)];
    check("vcs=1 ≡ legacy (link down)", 8, |g| {
        let count = g.usize(10..120);
        let specs = random_specs(g.rng(), count);
        let (a, b, at) = cuts[g.usize(0..cuts.len())];
        let seed = g.u64(0..1 << 48);
        let make = move || {
            FaultModel::new(seed)
                .with_ber(5e-5)
                .with_link_down(NodeId(a), NodeId(b), at)
        };
        assert_stat_identical(&specs, Some(&make));
    });
}

#[test]
fn vc1_predropped_link_routes_by_table_exactly_like_legacy() {
    // The link dies at cycle 0, before any flit exists: both sides run
    // the pure table-routed discipline from the first injection on.
    let specs: Vec<PacketSpec> = (0..40u64)
        .map(|k| {
            PacketSpec::new(
                NodeId((k * 3 % 16) as u16),
                NodeId((k * 7 % 16) as u16),
                128 * (1 + k % 9),
                k * 3,
            )
        })
        .filter(|s| s.src != s.dest)
        .collect();
    let make = || FaultModel::new(13).with_link_down(NodeId(1), NodeId(2), 0);
    assert_stat_identical(&specs, Some(&make));
}

#[test]
fn vc1_seeded_fault_runs_replay_identically_after_refactor() {
    // Same seed, same config ⇒ bit-identical stats on the refactored
    // router — determinism survived the input/output-control split.
    let run = || {
        let mut net = Network::new(vcs1_cfg());
        net.set_fault_model(
            FaultModel::new(4242)
                .with_ber(1e-4)
                .with_drop(0.03)
                .with_dup(0.02)
                .with_link_down(NodeId(5), NodeId(9), 200),
        );
        let mut rng = Rng::new(7);
        let specs = random_specs(&mut rng, 120);
        net.schedule_packets(&specs);
        net.run_to_completion(1_000_000)
    };
    assert_eq!(run(), run());
}

#[test]
fn prop_multi_vc_soak_is_deadlock_free_with_exact_accounting() {
    // The other half of the satellite: whatever vcs > 1 does, it must
    // never wedge — random traffic × faults × a mid-run cut always
    // drains (escape channel guarantees progress), with every packet
    // delivered, dropped (budget), or typed unreachable, and a clean
    // per-VC credit audit at the end.
    check("multi-VC deadlock-freedom soak", 6, |g| {
        let vcs = [2u8, 4][g.usize(0..2)];
        let count = g.usize(20..140);
        let specs = random_specs(g.rng(), count);
        let n = specs.len() as u64;
        let seed = g.u64(0..1 << 48);
        let mut net = Network::new(vcs1_cfg().with_vcs(vcs));
        net.set_fault_model(
            FaultModel::new(seed)
                .with_ber(1e-4)
                .with_drop(0.01)
                .with_link_down(NodeId(5), NodeId(6), 100),
        );
        net.schedule_packets(&specs);
        let stats = net
            .try_run_to_completion(1_000_000)
            .expect("multi-VC network must never wedge");
        assert_eq!(
            stats.delivered_packets
                + stats.packets_dropped
                + stats.packets_unreachable,
            n,
            "packet accounting leaked"
        );
        assert!(net.audit_credits().is_empty(), "per-VC credit leak");
    });
}
