//! Block-level model configurations for the three evaluated LLMs.
//!
//! Checkpoints are unavailable offline, so each model exists at two scales:
//!
//! * [`ModelScale::Paper`] — dimensions chosen to land on the published
//!   parameter counts (Jamba-tiny-dev ≈ 319 M, Zamba2 ≈ 1.2 B, Qwen1.5 ≈
//!   1.8 B) with the right block mix; used by the analytic traffic model.
//! * [`ModelScale::Tiny`] — a few-million-parameter variant with the same
//!   block mix, runnable through the JAX/Pallas AOT path
//!   (`python/compile/model.py` mirrors these dimensions exactly).

/// The kind of a transformer/hybrid block.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum BlockKind {
    /// Multi-head self-attention (+ per-block MLP where configured).
    Attention,
    /// Mamba selective-state-space block.
    Mamba,
    /// Mixture-of-experts MLP.
    Moe,
    /// Dense MLP.
    Mlp,
}

/// Model scale variant.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModelScale {
    Paper,
    Tiny,
}

/// A block-structured model description.
#[derive(Clone, Debug)]
pub struct ModelConfig {
    pub name: &'static str,
    pub scale: ModelScale,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub d_ff: usize,
    /// Expert hidden size (MoE blocks).
    pub d_ff_expert: usize,
    pub n_experts: usize,
    pub top_k: usize,
    /// SSM state dimension per channel.
    pub d_state: usize,
    /// Mamba inner width (usually 2·d_model).
    pub d_inner: usize,
    /// Depthwise conv width in the Mamba block.
    pub d_conv: usize,
    pub vocab: usize,
    /// Whether input/output embeddings share weights.
    pub tied_embeddings: bool,
    pub blocks: Vec<BlockKind>,
}

impl ModelConfig {
    /// Head dimension.
    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// Parameters in one block of the given kind.
    pub fn block_params(&self, kind: BlockKind) -> u64 {
        let d = self.d_model as u64;
        match kind {
            // QKV + output projections (KV possibly grouped).
            BlockKind::Attention => {
                let kv = (self.n_kv_heads * self.head_dim()) as u64;
                d * d * 2 + d * kv * 2
            }
            // in-proj (x,z) + conv + Δ/B/C projections + out-proj.
            BlockKind::Mamba => {
                let di = self.d_inner as u64;
                let ds = self.d_state as u64;
                d * di * 2           // in-proj to (x, z)
                    + di * self.d_conv as u64
                    + di * (ds * 2 + 1) // B, C, Δ projections (low-rank Δ folded)
                    + di * ds           // A (log) parameter
                    + di * d            // out-proj
            }
            BlockKind::Moe => {
                let e = self.n_experts as u64;
                let dfe = self.d_ff_expert as u64;
                e * (d * dfe * 3) + d * e // gated experts + router
            }
            BlockKind::Mlp => d * self.d_ff as u64 * 3,
        }
    }

    /// Embedding (+ unembedding) parameters.
    pub fn embedding_params(&self) -> u64 {
        let e = (self.vocab * self.d_model) as u64;
        if self.tied_embeddings {
            e
        } else {
            2 * e
        }
    }

    /// Total parameter count.
    pub fn total_params(&self) -> u64 {
        self.embedding_params()
            + self
                .blocks
                .iter()
                .map(|&k| self.block_params(k))
                .sum::<u64>()
    }

    /// Bytes of BF16 weights resident on compute chiplets (embeddings are
    /// kept at the memory chiplets and streamed per token, so block
    /// weights are what the WeightLoad phase moves).
    pub fn block_weight_bytes(&self) -> u64 {
        self.blocks
            .iter()
            .map(|&k| self.block_params(k) * 2)
            .sum()
    }

    /// Per-token activation bytes crossing a block boundary.
    pub fn act_bytes_per_token(&self) -> u64 {
        self.d_model as u64 * 2
    }

    /// Per-token KV-cache bytes appended by one attention block.
    pub fn kv_bytes_per_token(&self) -> u64 {
        (2 * self.n_kv_heads * self.head_dim()) as u64 * 2
    }

    /// SSM recurrent-state bytes of one Mamba block (sequence-length
    /// independent — the hybrid models' key property).
    pub fn ssm_state_bytes(&self) -> u64 {
        (self.d_inner * self.d_state + self.d_inner * (self.d_conv - 1)) as u64 * 2
    }

    /// Approximate FLOPs for one token through one block (decode).
    pub fn block_flops_per_token(&self, kind: BlockKind, context_len: u64) -> u64 {
        let d = self.d_model as u64;
        match kind {
            BlockKind::Attention => {
                let kv = (self.n_kv_heads * self.head_dim()) as u64;
                // Projections + attention over the running context.
                2 * (d * d * 2 + d * kv * 2) + 4 * context_len * d
            }
            BlockKind::Mamba => 2 * self.block_params(BlockKind::Mamba),
            BlockKind::Moe => {
                let dfe = self.d_ff_expert as u64;
                2 * (self.top_k as u64) * d * dfe * 3 + 2 * d * self.n_experts as u64
            }
            BlockKind::Mlp => 2 * d * self.d_ff as u64 * 3,
        }
    }

    // --- the three evaluated models -------------------------------------

    /// Jamba-tiny-dev-like hybrid (paper scale ≈ 319 M params): mostly
    /// Mamba with interleaved attention and MoE blocks (Jamba's 1:7
    /// attention:Mamba ratio, MoE every other layer, scaled down).
    pub fn jamba(scale: ModelScale) -> Self {
        match scale {
            ModelScale::Paper => {
                let blocks = vec![
                    BlockKind::Mamba,
                    BlockKind::Moe,
                    BlockKind::Mamba,
                    BlockKind::Mlp,
                    BlockKind::Attention,
                    BlockKind::Moe,
                    BlockKind::Mamba,
                    BlockKind::Mlp,
                    BlockKind::Mamba,
                    BlockKind::Moe,
                    BlockKind::Mamba,
                    BlockKind::Mlp,
                ];
                ModelConfig {
                    name: "jamba-tiny-dev",
                    scale,
                    d_model: 1024,
                    n_heads: 16,
                    n_kv_heads: 8,
                    d_ff: 4096,
                    d_ff_expert: 2048,
                    n_experts: 8,
                    top_k: 2,
                    d_state: 16,
                    d_inner: 2048,
                    d_conv: 4,
                    vocab: 65536,
                    tied_embeddings: true,
                    blocks,
                }
            }
            ModelScale::Tiny => ModelConfig {
                name: "jamba-tiny",
                scale,
                d_model: 128,
                n_heads: 4,
                n_kv_heads: 2,
                d_ff: 512,
                d_ff_expert: 256,
                n_experts: 4,
                top_k: 2,
                d_state: 16,
                d_inner: 256,
                d_conv: 4,
                vocab: 1024,
                tied_embeddings: true,
                blocks: vec![
                    BlockKind::Mamba,
                    BlockKind::Attention,
                    BlockKind::Moe,
                    BlockKind::Mamba,
                ],
            },
        }
    }

    /// Zamba2-1.2B-like hybrid (paper scale ≈ 1.2 B): a deep Mamba
    /// backbone with periodically applied shared attention blocks.
    pub fn zamba(scale: ModelScale) -> Self {
        match scale {
            ModelScale::Paper => {
                let mut blocks = Vec::new();
                for i in 0..30 {
                    blocks.push(BlockKind::Mamba);
                    if i % 10 == 9 {
                        blocks.push(BlockKind::Attention);
                        blocks.push(BlockKind::Mlp);
                    }
                }
                ModelConfig {
                    name: "zamba2-1.2b",
                    scale,
                    d_model: 2048,
                    n_heads: 32,
                    n_kv_heads: 32,
                    d_ff: 8192,
                    d_ff_expert: 0,
                    n_experts: 0,
                    top_k: 0,
                    d_state: 64,
                    d_inner: 4096,
                    d_conv: 4,
                    vocab: 32000,
                    tied_embeddings: true,
                    blocks,
                }
            }
            ModelScale::Tiny => {
                let mut blocks = Vec::new();
                for i in 0..4 {
                    blocks.push(BlockKind::Mamba);
                    if i == 3 {
                        blocks.push(BlockKind::Attention);
                    }
                }
                ModelConfig {
                    name: "zamba-tiny",
                    scale,
                    d_model: 128,
                    n_heads: 4,
                    n_kv_heads: 4,
                    d_ff: 512,
                    d_ff_expert: 0,
                    n_experts: 0,
                    top_k: 0,
                    d_state: 16,
                    d_inner: 256,
                    d_conv: 4,
                    vocab: 1024,
                    tied_embeddings: true,
                    blocks,
                }
            }
        }
    }

    /// Qwen1.5-1.8B-like transformer (paper scale ≈ 1.8 B): attention +
    /// dense MLP throughout (the transformer-only comparison point).
    pub fn qwen(scale: ModelScale) -> Self {
        match scale {
            ModelScale::Paper => {
                let mut blocks = Vec::new();
                for _ in 0..24 {
                    blocks.push(BlockKind::Attention);
                    blocks.push(BlockKind::Mlp);
                }
                ModelConfig {
                    name: "qwen1.5-1.8b",
                    scale,
                    d_model: 2048,
                    n_heads: 16,
                    n_kv_heads: 16,
                    d_ff: 5504,
                    d_ff_expert: 0,
                    n_experts: 0,
                    top_k: 0,
                    d_state: 0,
                    d_inner: 0,
                    d_conv: 1,
                    vocab: 151936,
                    tied_embeddings: true,
                    blocks,
                }
            }
            ModelScale::Tiny => {
                let mut blocks = Vec::new();
                for _ in 0..3 {
                    blocks.push(BlockKind::Attention);
                    blocks.push(BlockKind::Mlp);
                }
                ModelConfig {
                    name: "qwen-tiny",
                    scale,
                    d_model: 128,
                    n_heads: 4,
                    n_kv_heads: 4,
                    d_ff: 512,
                    d_ff_expert: 0,
                    n_experts: 0,
                    top_k: 0,
                    d_state: 0,
                    d_inner: 0,
                    d_conv: 1,
                    vocab: 1024,
                    tied_embeddings: true,
                    blocks,
                }
            }
        }
    }

    /// All three paper-scale models (the evaluation set).
    pub fn paper_models() -> Vec<ModelConfig> {
        vec![
            ModelConfig::jamba(ModelScale::Paper),
            ModelConfig::zamba(ModelScale::Paper),
            ModelConfig::qwen(ModelScale::Paper),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn within_pct(v: u64, target: u64, pct: f64) -> bool {
        let v = v as f64;
        let t = target as f64;
        (v - t).abs() <= t * pct / 100.0
    }

    #[test]
    fn paper_param_counts() {
        let j = ModelConfig::jamba(ModelScale::Paper).total_params();
        let z = ModelConfig::zamba(ModelScale::Paper).total_params();
        let q = ModelConfig::qwen(ModelScale::Paper).total_params();
        assert!(within_pct(j, 319_000_000, 25.0), "jamba {j}");
        assert!(within_pct(z, 1_200_000_000, 25.0), "zamba {z}");
        assert!(within_pct(q, 1_800_000_000, 25.0), "qwen {q}");
    }

    #[test]
    fn tiny_models_are_small() {
        for cfg in [
            ModelConfig::jamba(ModelScale::Tiny),
            ModelConfig::zamba(ModelScale::Tiny),
            ModelConfig::qwen(ModelScale::Tiny),
        ] {
            let p = cfg.total_params();
            assert!(p < 25_000_000, "{} has {p} params", cfg.name);
        }
    }

    #[test]
    fn hybrid_state_is_sequence_independent() {
        let z = ModelConfig::zamba(ModelScale::Paper);
        // SSM state bytes do not depend on sequence length — the fixed
        // size is the hybrid models' selling point.
        assert!(z.ssm_state_bytes() > 0);
        // KV grows per token.
        assert!(z.kv_bytes_per_token() > 0);
    }

    #[test]
    fn block_mix_matches_architectures() {
        let j = ModelConfig::jamba(ModelScale::Paper);
        assert!(j.blocks.contains(&BlockKind::Moe));
        assert!(j.blocks.contains(&BlockKind::Mamba));
        assert!(j.blocks.contains(&BlockKind::Attention));
        let q = ModelConfig::qwen(ModelScale::Paper);
        assert!(!q.blocks.contains(&BlockKind::Mamba));
        let z = ModelConfig::zamba(ModelScale::Paper);
        assert!(
            z.blocks.iter().filter(|&&b| b == BlockKind::Mamba).count()
                > z.blocks.len() * 2 / 3
        );
    }
}
