//! Streaming synthetic weight tensors.
//!
//! Paper-scale checkpoints are unavailable offline, so weight exponent
//! streams are synthesized per layer from fan-in-scaled Gaussian (or
//! Laplace) distributions — the distribution family trained LLM weights
//! empirically follow, and the property that yields the paper's <3-bit
//! exponent entropy. Streams are generated in chunks so multi-GB models
//! never materialize.

use crate::config::{BlockKind, ModelConfig};
use lexi_core::prng::Rng;
use lexi_core::Bf16;

/// Distribution family for synthetic tensors.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Family {
    Gaussian,
    /// Heavier tails — widens the exponent histogram slightly.
    Laplace,
}

/// A streaming generator of BF16 weight values for one block.
pub struct WeightStream {
    rng: Rng,
    sigma: f64,
    family: Family,
    remaining: u64,
}

impl WeightStream {
    /// Stream for block `layer` of `cfg`. σ = 1/√fan_in matches both the
    /// init scale and the empirical magnitude of trained weights.
    pub fn for_block(cfg: &ModelConfig, layer: usize, seed: u64) -> Self {
        let kind = cfg.blocks[layer];
        let fan_in = match kind {
            BlockKind::Attention | BlockKind::Mamba => cfg.d_model,
            BlockKind::Moe => cfg.d_ff_expert.max(cfg.d_model),
            BlockKind::Mlp => cfg.d_ff.max(cfg.d_model),
        } as f64;
        WeightStream {
            rng: Rng::new(seed ^ fnv(cfg.name) ^ (layer as u64).wrapping_mul(0x9E37)),
            sigma: 1.0 / fan_in.sqrt(),
            family: Family::Gaussian,
            remaining: cfg.block_params(kind),
        }
    }

    /// Override the distribution family (entropy-sensitivity ablation).
    pub fn with_family(mut self, family: Family) -> Self {
        self.family = family;
        self
    }

    /// Values left in this stream.
    pub fn remaining(&self) -> u64 {
        self.remaining
    }

    /// Produce up to `n` BF16 values (fewer at end of stream).
    pub fn next_values(&mut self, n: usize) -> Vec<Bf16> {
        let take = (self.remaining.min(n as u64)) as usize;
        self.remaining -= take as u64;
        (0..take)
            .map(|_| {
                let x = match self.family {
                    Family::Gaussian => self.rng.normal() * self.sigma,
                    Family::Laplace => self.rng.laplace(self.sigma / std::f64::consts::SQRT_2),
                };
                Bf16::from_f32(x as f32)
            })
            .collect()
    }

    /// Produce up to `n` exponent bytes (the codec-facing fast path).
    pub fn next_exponents(&mut self, n: usize) -> Vec<u8> {
        self.next_values(n).iter().map(|v| v.exponent()).collect()
    }

    /// Sample `n` exponents without consuming the stream budget (for CR
    /// estimation on huge blocks: the stream is i.i.d., so a sample's
    /// histogram converges to the block's).
    pub fn sample_exponents(cfg: &ModelConfig, layer: usize, seed: u64, n: usize) -> Vec<u8> {
        let mut s = WeightStream::for_block(cfg, layer, seed);
        s.remaining = n as u64;
        s.next_exponents(n)
    }
}

fn fnv(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelScale;
    use lexi_core::stats::Histogram;

    #[test]
    fn stream_is_deterministic_and_bounded() {
        let cfg = ModelConfig::jamba(ModelScale::Tiny);
        let mut a = WeightStream::for_block(&cfg, 0, 1);
        let mut b = WeightStream::for_block(&cfg, 0, 1);
        assert_eq!(a.next_values(100), b.next_values(100));
        let total = cfg.block_params(cfg.blocks[0]);
        let mut s = WeightStream::for_block(&cfg, 0, 1);
        let mut seen = 0u64;
        loop {
            let chunk = s.next_values(1 << 16);
            if chunk.is_empty() {
                break;
            }
            seen += chunk.len() as u64;
        }
        assert_eq!(seen, total);
    }

    #[test]
    fn exponent_entropy_matches_paper_claim() {
        // <3-bit entropy, <32 distinct dominating values (Fig 1a).
        let cfg = ModelConfig::qwen(ModelScale::Paper);
        let exps = WeightStream::sample_exponents(&cfg, 0, 7, 300_000);
        let h = Histogram::from_bytes(&exps);
        assert!(h.entropy_bits() < 3.5, "entropy {}", h.entropy_bits());
        assert!(h.top_k_mass(32) > 0.999, "mass {}", h.top_k_mass(32));
    }

    #[test]
    fn different_layers_have_different_streams() {
        let cfg = ModelConfig::jamba(ModelScale::Paper);
        let a = WeightStream::sample_exponents(&cfg, 0, 1, 64);
        let b = WeightStream::sample_exponents(&cfg, 1, 1, 64);
        assert_ne!(a, b);
    }

    #[test]
    fn laplace_widens_entropy() {
        let cfg = ModelConfig::qwen(ModelScale::Paper);
        let g = {
            let mut s = WeightStream::for_block(&cfg, 0, 3);
            s.next_exponents(200_000)
        };
        let l = {
            let mut s = WeightStream::for_block(&cfg, 0, 3).with_family(Family::Laplace);
            s.next_exponents(200_000)
        };
        let hg = Histogram::from_bytes(&g).entropy_bits();
        let hl = Histogram::from_bytes(&l).entropy_bits();
        assert!(hl > hg, "laplace {hl} vs gaussian {hg}");
    }
}
