//! Logical transfer generation for prefill + autoregressive decode.
//!
//! Mirrors the paper's §5.1 dataflow on the Simba array:
//! * **Weights** are loaded once from memory chiplets and stay resident
//!   (that is why "compressed weights only" barely moves Table 3).
//! * **Activations** cross chiplets at every block boundary, every token.
//! * **Hybrid caches** (attention KV + Mamba SSM state) are written back
//!   to memory block-by-block and fetched just before use — the dominant,
//!   sequence-length-dependent traffic in decode.
//!
//! Transfers are *logical* (endpoint = memory or block); `lexi-sim` maps
//! endpoints onto mesh nodes and applies compression ratios.

use crate::config::{BlockKind, ModelConfig};
use crate::corpus::Corpus;

/// What a transfer carries (determines its compressibility class).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TransferKind {
    Weights,
    Activation,
    KvCache,
    SsmState,
}

impl TransferKind {
    /// Every traffic class, Table 3 reporting order.
    pub const ALL: [TransferKind; 4] = [
        TransferKind::Weights,
        TransferKind::Activation,
        TransferKind::KvCache,
        TransferKind::SsmState,
    ];
}

/// Inference phase.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Phase {
    WeightLoad,
    Prefill,
    /// Decode step index (0-based).
    Decode(u32),
}

/// A logical endpoint.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Endpoint {
    /// Nearest memory chiplet (resolved by the system mapping).
    Memory,
    /// The chiplet hosting block `layer`.
    Block(usize),
}

/// One logical transfer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TransferSpec {
    pub phase: Phase,
    pub layer: usize,
    pub kind: TransferKind,
    pub src: Endpoint,
    pub dst: Endpoint,
    /// Uncompressed payload size in bytes (BF16).
    pub bytes: u64,
}

/// Generate the one-time weight-load transfers.
pub fn weight_load(cfg: &ModelConfig) -> Vec<TransferSpec> {
    cfg.blocks
        .iter()
        .enumerate()
        .map(|(layer, &kind)| TransferSpec {
            phase: Phase::WeightLoad,
            layer,
            kind: TransferKind::Weights,
            src: Endpoint::Memory,
            dst: Endpoint::Block(layer),
            bytes: cfg.block_params(kind) * 2,
        })
        .collect()
}

/// Generate prefill transfers for the whole input sequence.
pub fn prefill(cfg: &ModelConfig, corpus: &Corpus) -> Vec<TransferSpec> {
    let n = corpus.input_tokens as u64;
    let mut out = Vec::new();
    for (layer, &kind) in cfg.blocks.iter().enumerate() {
        // Input activations: embeddings from memory for block 0, else from
        // the previous block's chiplet.
        out.push(TransferSpec {
            phase: Phase::Prefill,
            layer,
            kind: TransferKind::Activation,
            src: if layer == 0 {
                Endpoint::Memory
            } else {
                Endpoint::Block(layer - 1)
            },
            dst: Endpoint::Block(layer),
            bytes: n * cfg.act_bytes_per_token(),
        });
        match kind {
            BlockKind::Attention => out.push(TransferSpec {
                phase: Phase::Prefill,
                layer,
                kind: TransferKind::KvCache,
                src: Endpoint::Block(layer),
                dst: Endpoint::Memory,
                bytes: n * cfg.kv_bytes_per_token(),
            }),
            BlockKind::Mamba => out.push(TransferSpec {
                phase: Phase::Prefill,
                layer,
                kind: TransferKind::SsmState,
                src: Endpoint::Block(layer),
                dst: Endpoint::Memory,
                bytes: cfg.ssm_state_bytes(),
            }),
            _ => {}
        }
    }
    // Final logits path back to memory (sampled there).
    out.push(TransferSpec {
        phase: Phase::Prefill,
        layer: cfg.blocks.len() - 1,
        kind: TransferKind::Activation,
        src: Endpoint::Block(cfg.blocks.len() - 1),
        dst: Endpoint::Memory,
        bytes: cfg.act_bytes_per_token(),
    });
    out
}

/// Generate one decode step's transfers (`step` 0-based; the attention
/// context is `input_tokens + step`).
pub fn decode_step(cfg: &ModelConfig, corpus: &Corpus, step: u32) -> Vec<TransferSpec> {
    let context = corpus.input_tokens as u64 + step as u64;
    let phase = Phase::Decode(step);
    let mut out = Vec::new();
    for (layer, &kind) in cfg.blocks.iter().enumerate() {
        out.push(TransferSpec {
            phase,
            layer,
            kind: TransferKind::Activation,
            src: if layer == 0 {
                Endpoint::Memory
            } else {
                Endpoint::Block(layer - 1)
            },
            dst: Endpoint::Block(layer),
            bytes: cfg.act_bytes_per_token(),
        });
        match kind {
            BlockKind::Attention => {
                // Fetch the whole running KV for this block, append one slot.
                out.push(TransferSpec {
                    phase,
                    layer,
                    kind: TransferKind::KvCache,
                    src: Endpoint::Memory,
                    dst: Endpoint::Block(layer),
                    bytes: context * cfg.kv_bytes_per_token(),
                });
                out.push(TransferSpec {
                    phase,
                    layer,
                    kind: TransferKind::KvCache,
                    src: Endpoint::Block(layer),
                    dst: Endpoint::Memory,
                    bytes: cfg.kv_bytes_per_token(),
                });
            }
            BlockKind::Mamba => {
                out.push(TransferSpec {
                    phase,
                    layer,
                    kind: TransferKind::SsmState,
                    src: Endpoint::Memory,
                    dst: Endpoint::Block(layer),
                    bytes: cfg.ssm_state_bytes(),
                });
                out.push(TransferSpec {
                    phase,
                    layer,
                    kind: TransferKind::SsmState,
                    src: Endpoint::Block(layer),
                    dst: Endpoint::Memory,
                    bytes: cfg.ssm_state_bytes(),
                });
            }
            _ => {}
        }
    }
    // Logits to memory for sampling.
    out.push(TransferSpec {
        phase,
        layer: cfg.blocks.len() - 1,
        kind: TransferKind::Activation,
        src: Endpoint::Block(cfg.blocks.len() - 1),
        dst: Endpoint::Memory,
        bytes: cfg.act_bytes_per_token(),
    });
    out
}

/// All transfers of a full inference (weight load + prefill + decode).
pub fn full_inference(cfg: &ModelConfig, corpus: &Corpus) -> Vec<TransferSpec> {
    let mut out = weight_load(cfg);
    out.extend(prefill(cfg, corpus));
    for t in 0..corpus.output_tokens as u32 {
        out.extend(decode_step(cfg, corpus, t));
    }
    out
}

/// Aggregate bytes by transfer kind.
pub fn volume_by_kind(transfers: &[TransferSpec]) -> std::collections::HashMap<TransferKind, u64> {
    let mut m = std::collections::HashMap::new();
    for t in transfers {
        *m.entry(t.kind).or_insert(0) += t.bytes;
    }
    m
}

/// Aggregate bytes by the *block kind* the transfer belongs to (Fig 1c's
/// Mamba / Transformer / MoE break-down). Weight-load traffic is excluded
/// (Fig 1c is about runtime communication).
pub fn volume_by_block_kind(
    cfg: &ModelConfig,
    transfers: &[TransferSpec],
) -> std::collections::HashMap<BlockKind, u64> {
    let mut m = std::collections::HashMap::new();
    for t in transfers {
        if t.phase == Phase::WeightLoad {
            continue;
        }
        *m.entry(cfg.blocks[t.layer]).or_insert(0) += t.bytes;
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelScale;

    #[test]
    fn weight_load_moves_every_block_once() {
        let cfg = ModelConfig::qwen(ModelScale::Paper);
        let w = weight_load(&cfg);
        assert_eq!(w.len(), cfg.blocks.len());
        let total: u64 = w.iter().map(|t| t.bytes).sum();
        assert_eq!(total, cfg.block_weight_bytes());
    }

    #[test]
    fn kv_traffic_grows_with_context() {
        let cfg = ModelConfig::qwen(ModelScale::Paper);
        let corpus = Corpus::wikitext2();
        let d0: u64 = decode_step(&cfg, &corpus, 0)
            .iter()
            .filter(|t| t.kind == TransferKind::KvCache)
            .map(|t| t.bytes)
            .sum();
        let d511: u64 = decode_step(&cfg, &corpus, 511)
            .iter()
            .filter(|t| t.kind == TransferKind::KvCache)
            .map(|t| t.bytes)
            .sum();
        assert!(d511 > d0);
    }

    #[test]
    fn mamba_state_traffic_is_flat() {
        let cfg = ModelConfig::zamba(ModelScale::Paper);
        let corpus = Corpus::wikitext2();
        let s = |step| -> u64 {
            decode_step(&cfg, &corpus, step)
                .iter()
                .filter(|t| t.kind == TransferKind::SsmState)
                .map(|t| t.bytes)
                .sum()
        };
        assert_eq!(s(0), s(511));
    }

    #[test]
    fn decode_dominates_comm_for_transformers() {
        // The memory-wall premise: decode-phase traffic ≫ prefill traffic
        // for a KV-heavy transformer.
        let cfg = ModelConfig::qwen(ModelScale::Paper);
        let corpus = Corpus::wikitext2();
        let pre: u64 = prefill(&cfg, &corpus).iter().map(|t| t.bytes).sum();
        let dec: u64 = (0..512)
            .flat_map(|t| decode_step(&cfg, &corpus, t))
            .map(|t| t.bytes)
            .sum();
        assert!(dec > pre * 10, "prefill {pre} decode {dec}");
    }

    #[test]
    fn hybrid_reduces_cache_traffic_vs_transformer() {
        // The hybrid-model premise (paper §1): replacing attention with
        // Mamba slashes cache traffic per parameter.
        let corpus = Corpus::wikitext2();
        let cache_bytes = |cfg: &ModelConfig| -> u64 {
            (0..512u32)
                .flat_map(|t| decode_step(cfg, &corpus, t))
                .filter(|t| matches!(t.kind, TransferKind::KvCache | TransferKind::SsmState))
                .map(|t| t.bytes)
                .sum()
        };
        let z = ModelConfig::zamba(ModelScale::Paper);
        let q = ModelConfig::qwen(ModelScale::Paper);
        let z_per_param = cache_bytes(&z) as f64 / z.total_params() as f64;
        let q_per_param = cache_bytes(&q) as f64 / q.total_params() as f64;
        assert!(
            z_per_param < q_per_param,
            "zamba {z_per_param} vs qwen {q_per_param}"
        );
    }

    #[test]
    fn volume_by_kind_sums_to_total() {
        let cfg = ModelConfig::jamba(ModelScale::Paper);
        let transfers = full_inference(&cfg, &Corpus::wikitext2());
        let total: u64 = transfers.iter().map(|t| t.bytes).sum();
        let by_kind = volume_by_kind(&transfers);
        assert_eq!(by_kind.values().sum::<u64>(), total);
    }
}
