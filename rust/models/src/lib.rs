//! # lexi-models — hybrid-LLM model configs, synthetic tensors, corpora
//!
//! The paper evaluates Jamba-tiny-dev (319M), Zamba2-1.2B and Qwen1.5-1.8B
//! on WikiText-2 (1K input tokens) and C4 (2K input tokens), 512 output
//! tokens. Checkpoints and datasets are not available offline, so this
//! crate provides architecture-faithful substitutes (documented in
//! DESIGN.md):
//!
//! * [`config`] — block-level model descriptions (attention / Mamba / MoE /
//!   MLP mix, dimensions, parameter counts) at two scales: `paper` (true
//!   parameter counts, analytic traffic) and `tiny` (runnable in JAX via
//!   the AOT path; matches `python/compile/model.py`).
//! * [`weights`] — streaming synthetic weight tensors (Gaussian/Laplace
//!   with fan-in-scaled σ per layer); reproduces the <3-bit exponent
//!   entropy and <32-distinct-exponent concentration of trained LLMs
//!   without materializing billions of values.
//! * [`activations`] — synthetic activation/cache exponent streams for
//!   paper-scale runs (layer-norm-bounded σ), used where the real tiny
//!   model's tensors are not applicable.
//! * [`corpus`] — deterministic Zipf token streams standing in for
//!   WikiText-2 / C4 (traffic depends on sequence shape, not token
//!   identity).
//! * [`traffic`] — per-phase logical transfers (weights, activations,
//!   KV-cache, SSM-state) for prefill + autoregressive decode.
//! * [`policy`] — per-traffic-class codec assignment ([`CodecPolicy`]):
//!   which `lexi_core::codec::CodecKind` each kind travels under; plus
//!   graceful degradation (ISSUE 6): a [`DegradePolicy`]/`DegradeTracker`
//!   pair that falls a repeatedly-undecodable class back to `Raw`; and
//!   the two-threshold hysteresis controller (ISSUE 9):
//!   [`HysteresisPolicy`]/[`DegradeController`] degrade on strikes *or*
//!   sustained codec-port occupancy, recover via single-transfer
//!   probes, and never flap inside the hysteresis window.

pub mod activations;
pub mod config;
pub mod corpus;
pub mod policy;
pub mod traffic;
pub mod weights;

pub use config::{BlockKind, ModelConfig, ModelScale};
pub use policy::{
    CodecPolicy, DegradeAction, DegradeController, DegradePolicy, DegradeTracker, HysteresisPolicy,
};
pub use traffic::{Phase, TransferKind, TransferSpec};
