//! Synthetic corpora standing in for WikiText-2 and C4.
//!
//! Inter-chiplet traffic depends on sequence shape (input/output lengths),
//! not token identity; token streams are Zipf-distributed ids so anything
//! content-sensitive (e.g. embedding-row locality studies) still sees
//! realistic frequencies. Sequence shapes follow the paper's setup:
//! WikiText-2 → 1 K input tokens, C4 → 2 K input tokens, both 512 output.

use lexi_core::prng::{Rng, Zipf};

/// A dataset stand-in.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Corpus {
    pub name: &'static str,
    pub input_tokens: usize,
    pub output_tokens: usize,
}

impl Corpus {
    /// WikiText-2 setup: 1 K input, 512 output.
    pub fn wikitext2() -> Self {
        Corpus {
            name: "wikitext-2",
            input_tokens: 1024,
            output_tokens: 512,
        }
    }

    /// C4 setup: 2 K input, 512 output.
    pub fn c4() -> Self {
        Corpus {
            name: "c4",
            input_tokens: 2048,
            output_tokens: 512,
        }
    }

    /// Both evaluation datasets.
    pub fn all() -> Vec<Corpus> {
        vec![Corpus::wikitext2(), Corpus::c4()]
    }

    /// A deterministic Zipf token stream of the input length.
    pub fn tokens(&self, vocab: usize, seed: u64) -> Vec<u32> {
        let mut rng = Rng::new(seed ^ fnv(self.name));
        let z = Zipf::new(vocab, 1.05);
        (0..self.input_tokens)
            .map(|_| z.sample(&mut rng) as u32)
            .collect()
    }
}

fn fnv(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_sequence_shapes() {
        assert_eq!(Corpus::wikitext2().input_tokens, 1024);
        assert_eq!(Corpus::c4().input_tokens, 2048);
        assert_eq!(Corpus::wikitext2().output_tokens, 512);
        assert_eq!(Corpus::c4().output_tokens, 512);
    }

    #[test]
    fn tokens_in_vocab_and_deterministic() {
        let c = Corpus::wikitext2();
        let a = c.tokens(4096, 3);
        let b = c.tokens(4096, 3);
        assert_eq!(a, b);
        assert_eq!(a.len(), 1024);
        assert!(a.iter().all(|&t| (t as usize) < 4096));
    }

    #[test]
    fn corpora_differ() {
        let a = Corpus::wikitext2().tokens(4096, 3);
        let b = Corpus::c4().tokens(4096, 3);
        assert_ne!(a[..100], b[..100]);
    }
}
