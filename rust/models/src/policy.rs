//! Per-traffic-class codec policy (ISSUE 3).
//!
//! The paper compresses every traffic class with one codec (LEXI's
//! Huffman). With the [`ExpCodec`](lexi_core::codec::ExpCodec) layer the
//! codec becomes a per-[`TransferKind`] knob: SSM state vectors are small
//! and delta-local (a decent BDI fit with zero codebook startup), KV
//! cache and weights are frequency-concentrated (Huffman's home turf),
//! and `Raw` is the honest "don't touch it" point. `lexi-sim`'s `Engine`
//! carries a `CodecPolicy` so Table 3 can report mixed-codec operating
//! points; `lexi dse --what codec` sweeps them.

use crate::traffic::TransferKind;
use lexi_core::codec::CodecKind;

/// Which exponent codec each traffic class uses when a compression mode
/// compresses it at all (the mode still gates *whether* a kind is
/// compressed; the policy picks *how*).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CodecPolicy {
    pub weights: CodecKind,
    pub activation: CodecKind,
    pub kv_cache: CodecKind,
    pub ssm_state: CodecKind,
}

impl CodecPolicy {
    /// The same codec for every class.
    pub fn uniform(codec: CodecKind) -> Self {
        CodecPolicy {
            weights: codec,
            activation: codec,
            kv_cache: codec,
            ssm_state: codec,
        }
    }

    /// The paper's operating point: LEXI Huffman everywhere.
    pub fn lexi_default() -> Self {
        Self::uniform(CodecKind::Huffman)
    }

    /// A mixed hybrid-LLM point: BDI for the (delta-local, startup-
    /// sensitive) SSM state, Huffman for everything else.
    pub fn bdi_state() -> Self {
        CodecPolicy {
            ssm_state: CodecKind::Bdi,
            ..Self::lexi_default()
        }
    }

    /// The codec this policy assigns to `kind`.
    #[inline]
    pub fn codec_for(&self, kind: TransferKind) -> CodecKind {
        match kind {
            TransferKind::Weights => self.weights,
            TransferKind::Activation => self.activation,
            TransferKind::KvCache => self.kv_cache,
            TransferKind::SsmState => self.ssm_state,
        }
    }

    /// Reassign one class.
    pub fn set(&mut self, kind: TransferKind, codec: CodecKind) {
        match kind {
            TransferKind::Weights => self.weights = codec,
            TransferKind::Activation => self.activation = codec,
            TransferKind::KvCache => self.kv_cache = codec,
            TransferKind::SsmState => self.ssm_state = codec,
        }
    }

    /// Parse a CLI spec: a bare codec name applies uniformly
    /// (`huffman`), `bdi-state` is the mixed preset, and
    /// `kind=codec,...` pairs override the default per class
    /// (`ssm=bdi,kv=huffman`).
    pub fn parse(spec: &str) -> Result<Self, String> {
        match spec {
            "lexi" | "default" => return Ok(Self::lexi_default()),
            "bdi-state" => return Ok(Self::bdi_state()),
            _ => {}
        }
        if let Ok(codec) = CodecKind::parse(spec) {
            return Ok(Self::uniform(codec));
        }
        let mut policy = Self::lexi_default();
        for part in spec.split(',') {
            let (kind_s, codec_s) = part
                .split_once('=')
                .ok_or_else(|| format!("bad policy entry '{part}' (want kind=codec)"))?;
            let kind = match kind_s {
                "weights" | "w" => TransferKind::Weights,
                "act" | "activation" => TransferKind::Activation,
                "kv" | "kvcache" => TransferKind::KvCache,
                "ssm" | "state" => TransferKind::SsmState,
                other => return Err(format!("unknown traffic kind '{other}'")),
            };
            let codec = CodecKind::parse(codec_s).map_err(|e| e.to_string())?;
            policy.set(kind, codec);
        }
        Ok(policy)
    }

    /// Compact human-readable form (`w=huffman act=huffman kv=huffman
    /// ssm=bdi`).
    pub fn describe(&self) -> String {
        format!(
            "w={} act={} kv={} ssm={}",
            self.weights.name(),
            self.activation.name(),
            self.kv_cache.name(),
            self.ssm_state.name()
        )
    }
}

impl Default for CodecPolicy {
    fn default() -> Self {
        Self::lexi_default()
    }
}

/// Graceful-degradation knob (ISSUE 6): when a traffic class keeps
/// failing to decode (CRC NACKs that survive the NoC's retry budget),
/// the engine stops compressing that class rather than stalling the
/// pipeline on retransmissions — lossless first, fast second, but never
/// wedged.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DegradePolicy {
    /// Decode failures a single [`TransferKind`] may accumulate before
    /// its codec falls back to [`CodecKind::Raw`].
    pub failure_threshold: u32,
}

impl DegradePolicy {
    /// Paper-point default: three strikes per traffic class.
    pub fn paper_default() -> Self {
        DegradePolicy { failure_threshold: 3 }
    }
}

impl Default for DegradePolicy {
    fn default() -> Self {
        Self::paper_default()
    }
}

/// Per-traffic-class decode-failure accounting that drives
/// [`DegradePolicy`]. Indexed by [`TransferKind::ALL`] order.
///
/// Since ISSUE 9 degradation is reversible: the tracker remembers the
/// codec a class ran before its fall to `Raw`, and
/// [`DegradeTracker::recover`] restores it when a probe succeeds — the
/// *when* of both transitions is decided by [`DegradeController`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct DegradeTracker {
    failures: [u32; 4],
    degraded: [bool; 4],
    /// Codec each class ran before degradation (restore target).
    prior: [Option<CodecKind>; 4],
}

#[inline]
fn kind_index(kind: TransferKind) -> usize {
    match kind {
        TransferKind::Weights => 0,
        TransferKind::Activation => 1,
        TransferKind::KvCache => 2,
        TransferKind::SsmState => 3,
    }
}

impl DegradeTracker {
    /// A tracker with no failures recorded.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one decode failure for `kind`. Once the count reaches
    /// `policy.failure_threshold`, the class is marked degraded and its
    /// entry in `codec_policy` is rewritten to `Raw` (idempotent — later
    /// failures keep it Raw). Returns `true` iff this call flipped the
    /// class.
    pub fn record_failure(
        &mut self,
        kind: TransferKind,
        policy: DegradePolicy,
        codec_policy: &mut CodecPolicy,
    ) -> bool {
        let i = kind_index(kind);
        self.failures[i] = self.failures[i].saturating_add(1);
        if self.degraded[i] || self.failures[i] < policy.failure_threshold {
            return false;
        }
        self.degraded[i] = true;
        self.prior[i] = Some(codec_policy.codec_for(kind));
        codec_policy.set(kind, CodecKind::Raw);
        true
    }

    /// Degrade `kind` to `Raw` immediately, bypassing the strike count
    /// (ISSUE 9: congestion-driven degradation — sustained codec-port
    /// occupancy, not decode failures, tripped the
    /// [`DegradeController`]). Remembers the displaced codec for
    /// [`DegradeTracker::recover`]. Returns `true` iff this call
    /// flipped the class (idempotent on an already-degraded one).
    pub fn force_degrade(&mut self, kind: TransferKind, codec_policy: &mut CodecPolicy) -> bool {
        let i = kind_index(kind);
        if self.degraded[i] {
            return false;
        }
        self.degraded[i] = true;
        self.prior[i] = Some(codec_policy.codec_for(kind));
        codec_policy.set(kind, CodecKind::Raw);
        true
    }

    /// Un-degrade `kind` after a successful health probe (ISSUE 9):
    /// restores the codec the class ran before degradation (Huffman if
    /// unknown) and zeroes its strike count so stale failures cannot
    /// instantly re-trip the threshold. Returns `true` iff the class
    /// was degraded.
    pub fn recover(&mut self, kind: TransferKind, codec_policy: &mut CodecPolicy) -> bool {
        let i = kind_index(kind);
        if !self.degraded[i] {
            return false;
        }
        self.degraded[i] = false;
        self.failures[i] = 0;
        let restore = self.prior[i].take().unwrap_or(CodecKind::Huffman);
        codec_policy.set(kind, restore);
        true
    }

    /// Decode failures recorded for `kind`.
    pub fn failures(&self, kind: TransferKind) -> u32 {
        self.failures[kind_index(kind)]
    }

    /// Has `kind` been degraded to `Raw`?
    pub fn is_degraded(&self, kind: TransferKind) -> bool {
        self.degraded[kind_index(kind)]
    }

    /// Every degraded traffic class, [`TransferKind::ALL`] order.
    pub fn degraded_kinds(&self) -> Vec<TransferKind> {
        TransferKind::ALL
            .into_iter()
            .filter(|&k| self.is_degraded(k))
            .collect()
    }
}

/// Two-threshold degradation/recovery policy (ISSUE 9). Extends the
/// one-way [`DegradePolicy`] (strikes → Raw, forever) into a controller
/// with hysteresis:
///
/// * **degrade** when a class accumulates `strike_threshold` decode
///   failures *or* sustains codec-port occupancy ≥ `occupancy_high`
///   for `sustain_windows` consecutive observation windows;
/// * **probe** while degraded, once occupancy has sat ≤ `occupancy_low`
///   (with zero strikes) for `probe_interval` consecutive windows — a
///   single compressed transfer tests the waters;
/// * **recover** when the probe succeeds — and never flap: any two
///   transitions (in either direction) are at least
///   `hysteresis_windows` observation windows apart.
///
/// The low/high gap is the hysteresis band: occupancy between the two
/// thresholds neither degrades a healthy class nor probes a degraded
/// one, so an oscillating signal straddling one threshold cannot make
/// the policy oscillate with it.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct HysteresisPolicy {
    /// Decode failures before a class degrades (matches
    /// [`DegradePolicy::failure_threshold`]'s paper default).
    pub strike_threshold: u32,
    /// Occupancy at/above which a window counts as overloaded.
    pub occupancy_high: f64,
    /// Occupancy at/below which a degraded window counts as calm.
    pub occupancy_low: f64,
    /// Consecutive overloaded windows before degrading.
    pub sustain_windows: u32,
    /// Consecutive calm windows before a recovery probe is issued.
    pub probe_interval: u32,
    /// Minimum windows between any two transitions (flap guard).
    pub hysteresis_windows: u32,
}

impl HysteresisPolicy {
    /// Default operating point: three strikes, degrade above 85%
    /// occupancy sustained for 3 windows, probe after 4 calm windows
    /// below 60%, and at least 8 windows between transitions.
    pub fn paper_default() -> Self {
        HysteresisPolicy {
            strike_threshold: DegradePolicy::paper_default().failure_threshold,
            occupancy_high: 0.85,
            occupancy_low: 0.60,
            sustain_windows: 3,
            probe_interval: 4,
            hysteresis_windows: 8,
        }
    }
}

impl Default for HysteresisPolicy {
    fn default() -> Self {
        Self::paper_default()
    }
}

/// What the [`DegradeController`] wants done after an observation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DegradeAction {
    /// Steady state — nothing to do.
    None,
    /// Flip the class to `Raw` now (caller: `DegradeTracker::force_degrade`
    /// or the strike path).
    Degrade,
    /// Run one compressed probe transfer and report the outcome via
    /// [`DegradeController::on_probe_result`].
    Probe,
    /// Probe succeeded — restore the class (caller:
    /// `DegradeTracker::recover`).
    Recover,
}

/// Per-kind window state for the hysteresis controller.
#[derive(Clone, Copy, Debug, Default)]
struct KindWindowState {
    degraded: bool,
    /// Observation windows seen for this kind (the transition clock).
    window_clock: u64,
    /// Window index of the last transition, if any.
    last_transition: Option<u64>,
    /// Consecutive windows at/above `occupancy_high` (healthy side).
    hot_windows: u32,
    /// Decode failures accumulated while healthy.
    strikes: u32,
    /// Consecutive calm windows (degraded side).
    calm_windows: u32,
    degrades: u64,
    recoveries: u64,
    probes: u64,
}

/// The two-threshold hysteresis state machine (ISSUE 9). Pure control
/// logic — it owns no [`CodecPolicy`]; callers apply emitted
/// [`DegradeAction`]s through [`DegradeTracker`] (the `lexi-sim`
/// `Engine` does exactly that), which keeps the machine independently
/// testable and mirrors it 1:1 in `tools/logic_check.py` §[15].
#[derive(Clone, Debug)]
pub struct DegradeController {
    policy: HysteresisPolicy,
    state: [KindWindowState; 4],
}

impl DegradeController {
    /// A controller with every class healthy.
    pub fn new(policy: HysteresisPolicy) -> Self {
        DegradeController {
            policy,
            state: [KindWindowState::default(); 4],
        }
    }

    /// The configured thresholds.
    pub fn policy(&self) -> HysteresisPolicy {
        self.policy
    }

    /// Is the flap guard open for this kind (no transition within the
    /// last `hysteresis_windows` windows)?
    fn guard_open(&self, i: usize) -> bool {
        let s = &self.state[i];
        s.last_transition
            .map_or(true, |t| s.window_clock - t >= u64::from(self.policy.hysteresis_windows))
    }

    /// Feed one observation window for `kind`: the codec-port occupancy
    /// over the window (0..=1; callers clamp) and the decode failures
    /// (post-retry-budget CRC losses) it saw. Returns the action due.
    pub fn on_window(&mut self, kind: TransferKind, occupancy: f64, strikes: u32) -> DegradeAction {
        let i = kind_index(kind);
        self.state[i].window_clock += 1;
        let guard_open = self.guard_open(i);
        let p = self.policy;
        let s = &mut self.state[i];
        if !s.degraded {
            s.strikes = s.strikes.saturating_add(strikes);
            if occupancy >= p.occupancy_high {
                s.hot_windows = s.hot_windows.saturating_add(1);
            } else {
                s.hot_windows = 0;
            }
            let tripped =
                s.strikes >= p.strike_threshold || s.hot_windows >= p.sustain_windows;
            if tripped && guard_open {
                s.degraded = true;
                s.last_transition = Some(s.window_clock);
                s.degrades += 1;
                s.hot_windows = 0;
                s.strikes = 0;
                s.calm_windows = 0;
                return DegradeAction::Degrade;
            }
            DegradeAction::None
        } else {
            if strikes > 0 || occupancy > p.occupancy_low {
                s.calm_windows = 0;
                return DegradeAction::None;
            }
            s.calm_windows = s.calm_windows.saturating_add(1);
            if s.calm_windows >= p.probe_interval && guard_open {
                s.calm_windows = 0;
                s.probes += 1;
                return DegradeAction::Probe;
            }
            DegradeAction::None
        }
    }

    /// Report the outcome of a probe this controller asked for. A
    /// healthy probe recovers the class (the flap guard was already
    /// checked when the probe was issued); a failed probe restarts the
    /// calm-window count.
    pub fn on_probe_result(&mut self, kind: TransferKind, healthy: bool) -> DegradeAction {
        let s = &mut self.state[kind_index(kind)];
        if !s.degraded || !healthy {
            return DegradeAction::None;
        }
        s.degraded = false;
        s.last_transition = Some(s.window_clock);
        s.recoveries += 1;
        s.hot_windows = 0;
        s.strikes = 0;
        s.calm_windows = 0;
        DegradeAction::Recover
    }

    /// Is `kind` currently on the degraded side of the machine?
    pub fn is_degraded(&self, kind: TransferKind) -> bool {
        self.state[kind_index(kind)].degraded
    }

    /// Lifetime `(degrades, recoveries, probes)` for `kind`.
    pub fn counts(&self, kind: TransferKind) -> (u64, u64, u64) {
        let s = &self.state[kind_index(kind)];
        (s.degrades, s.recoveries, s.probes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_all_huffman() {
        let p = CodecPolicy::default();
        for kind in TransferKind::ALL {
            assert_eq!(p.codec_for(kind), CodecKind::Huffman);
        }
    }

    #[test]
    fn bdi_state_only_touches_ssm() {
        let p = CodecPolicy::bdi_state();
        assert_eq!(p.codec_for(TransferKind::SsmState), CodecKind::Bdi);
        assert_eq!(p.codec_for(TransferKind::KvCache), CodecKind::Huffman);
        assert_eq!(p.codec_for(TransferKind::Weights), CodecKind::Huffman);
    }

    #[test]
    fn parse_specs() {
        assert_eq!(
            CodecPolicy::parse("bdi").unwrap(),
            CodecPolicy::uniform(CodecKind::Bdi)
        );
        assert_eq!(CodecPolicy::parse("bdi-state").unwrap(), CodecPolicy::bdi_state());
        let p = CodecPolicy::parse("ssm=bdi,kv=raw").unwrap();
        assert_eq!(p.codec_for(TransferKind::SsmState), CodecKind::Bdi);
        assert_eq!(p.codec_for(TransferKind::KvCache), CodecKind::Raw);
        assert_eq!(p.codec_for(TransferKind::Activation), CodecKind::Huffman);
        assert!(CodecPolicy::parse("zstd").is_err());
        assert!(CodecPolicy::parse("kv:bdi").is_err());
    }

    #[test]
    fn set_and_describe() {
        let mut p = CodecPolicy::lexi_default();
        p.set(TransferKind::Weights, CodecKind::Raw);
        assert_eq!(p.codec_for(TransferKind::Weights), CodecKind::Raw);
        assert_eq!(p.describe(), "w=raw act=huffman kv=huffman ssm=huffman");
    }

    #[test]
    fn degrade_flips_to_raw_at_threshold_only() {
        let mut policy = CodecPolicy::lexi_default();
        let mut tracker = DegradeTracker::new();
        let dp = DegradePolicy::paper_default();
        assert!(!tracker.record_failure(TransferKind::Activation, dp, &mut policy));
        assert!(!tracker.record_failure(TransferKind::Activation, dp, &mut policy));
        assert_eq!(policy.codec_for(TransferKind::Activation), CodecKind::Huffman);
        assert!(!tracker.is_degraded(TransferKind::Activation));
        // Third strike flips it — and only it.
        assert!(tracker.record_failure(TransferKind::Activation, dp, &mut policy));
        assert_eq!(policy.codec_for(TransferKind::Activation), CodecKind::Raw);
        assert!(tracker.is_degraded(TransferKind::Activation));
        assert_eq!(policy.codec_for(TransferKind::KvCache), CodecKind::Huffman);
        assert_eq!(tracker.degraded_kinds(), vec![TransferKind::Activation]);
        // Idempotent after the flip: more failures don't "re-flip".
        assert!(!tracker.record_failure(TransferKind::Activation, dp, &mut policy));
        assert_eq!(tracker.failures(TransferKind::Activation), 4);
    }

    #[test]
    fn degrade_threshold_one_is_immediate() {
        let mut policy = CodecPolicy::lexi_default();
        let mut tracker = DegradeTracker::new();
        let dp = DegradePolicy { failure_threshold: 1 };
        assert!(tracker.record_failure(TransferKind::SsmState, dp, &mut policy));
        assert_eq!(policy.codec_for(TransferKind::SsmState), CodecKind::Raw);
        assert_eq!(policy.codec_for(TransferKind::Weights), CodecKind::Huffman);
    }

    #[test]
    fn recover_restores_the_displaced_codec_and_resets_strikes() {
        // ISSUE 9: the round-trip is lossless on the policy itself — a
        // BDI class that degrades comes back as BDI, not as Huffman.
        let mut policy = CodecPolicy::bdi_state();
        let mut tracker = DegradeTracker::new();
        let dp = DegradePolicy::paper_default();
        for _ in 0..3 {
            tracker.record_failure(TransferKind::SsmState, dp, &mut policy);
        }
        assert_eq!(policy.codec_for(TransferKind::SsmState), CodecKind::Raw);
        assert!(tracker.recover(TransferKind::SsmState, &mut policy));
        assert_eq!(policy.codec_for(TransferKind::SsmState), CodecKind::Bdi);
        assert!(!tracker.is_degraded(TransferKind::SsmState));
        assert_eq!(tracker.failures(TransferKind::SsmState), 0);
        // Idempotent: recovering a healthy class is a no-op.
        assert!(!tracker.recover(TransferKind::SsmState, &mut policy));
        // And the class can degrade again — fresh three strikes needed.
        assert!(!tracker.record_failure(TransferKind::SsmState, dp, &mut policy));
        assert!(!tracker.record_failure(TransferKind::SsmState, dp, &mut policy));
        assert!(tracker.record_failure(TransferKind::SsmState, dp, &mut policy));
    }

    #[test]
    fn force_degrade_bypasses_strikes_and_round_trips() {
        let mut policy = CodecPolicy::lexi_default();
        let mut tracker = DegradeTracker::new();
        assert!(tracker.force_degrade(TransferKind::KvCache, &mut policy));
        assert_eq!(policy.codec_for(TransferKind::KvCache), CodecKind::Raw);
        assert_eq!(tracker.degraded_kinds(), vec![TransferKind::KvCache]);
        assert!(!tracker.force_degrade(TransferKind::KvCache, &mut policy));
        assert!(tracker.recover(TransferKind::KvCache, &mut policy));
        assert_eq!(policy.codec_for(TransferKind::KvCache), CodecKind::Huffman);
        assert!(tracker.degraded_kinds().is_empty());
    }

    /// Satellite-3 pin: the scripted window sequence and its expected
    /// action trace are mirrored verbatim in `tools/logic_check.py`
    /// §[15] — change one side only with the other.
    #[test]
    fn hysteresis_round_trip_scripted_trace() {
        let p = HysteresisPolicy {
            strike_threshold: 3,
            occupancy_high: 0.85,
            occupancy_low: 0.60,
            sustain_windows: 3,
            probe_interval: 2,
            hysteresis_windows: 4,
        };
        let mut c = DegradeController::new(p);
        let k = TransferKind::KvCache;
        use DegradeAction::*;
        // (occupancy, strikes) → expected action, window by window.
        let script = [
            (0.95, 0, None),    // hot 1
            (0.50, 0, None),    // cooled — hot resets
            (0.95, 0, None),    // hot 1
            (0.95, 0, None),    // hot 2
            (0.95, 0, Degrade), // hot 3 → degrade (window 5)
            (0.95, 0, None),    // still hot: no probe while loaded
            (0.50, 0, None),    // calm 1
            (0.70, 0, None),    // between thresholds — calm resets
            (0.50, 0, None),    // calm 1 (window 9 ≥ 5+4: guard open)
            (0.50, 0, Probe),   // calm 2 → probe
        ];
        for (i, &(occ, strikes, want)) in script.iter().enumerate() {
            assert_eq!(c.on_window(k, occ, strikes), want, "window {}", i + 1);
        }
        assert!(c.is_degraded(k));
        assert_eq!(c.on_probe_result(k, true), Recover);
        assert!(!c.is_degraded(k));
        assert_eq!(c.counts(k), (1, 1, 1));
        // Strike path degrades too — but the flap guard holds it until
        // 4 windows after the recovery at window 10.
        assert_eq!(c.on_window(k, 0.10, 3), None); // window 11: guard closed
        assert_eq!(c.on_window(k, 0.10, 0), None);
        assert_eq!(c.on_window(k, 0.10, 0), None);
        assert_eq!(c.on_window(k, 0.10, 0), Degrade); // window 14: guard opens
        assert_eq!(c.counts(k), (2, 1, 1));
    }

    #[test]
    fn hysteresis_never_flaps_faster_than_the_window() {
        // Worst-case oscillating health: occupancy alternates far above
        // high and far below low every window, and every probe
        // succeeds. Transitions must still be ≥ hysteresis_windows
        // apart — the machine cannot track the oscillation.
        let p = HysteresisPolicy {
            strike_threshold: 3,
            occupancy_high: 0.85,
            occupancy_low: 0.60,
            sustain_windows: 1,
            probe_interval: 1,
            hysteresis_windows: 6,
        };
        let mut c = DegradeController::new(p);
        let k = TransferKind::Activation;
        let mut transitions: Vec<u64> = Vec::new();
        for w in 1..=200u64 {
            let occ = if w % 2 == 0 { 0.99 } else { 0.01 };
            match c.on_window(k, occ, 0) {
                DegradeAction::Degrade => transitions.push(w),
                DegradeAction::Probe => {
                    if c.on_probe_result(k, true) == DegradeAction::Recover {
                        transitions.push(w);
                    }
                }
                _ => {}
            }
        }
        assert!(
            transitions.len() >= 4,
            "oscillation produced too few transitions to check spacing: {transitions:?}"
        );
        for pair in transitions.windows(2) {
            assert!(
                pair[1] - pair[0] >= u64::from(p.hysteresis_windows),
                "flapped faster than the hysteresis window: {transitions:?}"
            );
        }
        let (d, r, _) = c.counts(k);
        // 200 windows / 6-window guard bounds the total transition count.
        assert!(d + r <= 200 / 6 + 1, "degrades {d} + recoveries {r}");
    }

    #[test]
    fn hysteresis_band_blocks_mid_band_oscillation_entirely() {
        // Occupancy bouncing *inside* the band (0.60, 0.85) must cause
        // zero transitions in either direction.
        let mut c = DegradeController::new(HysteresisPolicy::paper_default());
        let k = TransferKind::KvCache;
        for w in 0..100 {
            let occ = if w % 2 == 0 { 0.85 - 1e-9 } else { 0.60 + 1e-9 };
            assert_eq!(c.on_window(k, occ, 0), DegradeAction::None);
        }
        assert_eq!(c.counts(k), (0, 0, 0));
        // Same from the degraded side.
        let mut c = DegradeController::new(HysteresisPolicy::paper_default());
        for _ in 0..3 {
            c.on_window(k, 0.99, 0);
        }
        assert!(c.is_degraded(k));
        for w in 0..100 {
            let occ = if w % 2 == 0 { 0.84 } else { 0.61 };
            assert_eq!(c.on_window(k, occ, 0), DegradeAction::None);
        }
        assert!(c.is_degraded(k), "mid-band occupancy must not probe");
        assert_eq!(c.counts(k).2, 0);
    }

    #[test]
    fn failed_probe_keeps_the_class_degraded_and_restarts_calm_count() {
        let p = HysteresisPolicy {
            probe_interval: 2,
            hysteresis_windows: 1,
            ..HysteresisPolicy::paper_default()
        };
        let mut c = DegradeController::new(p);
        let k = TransferKind::Weights;
        for _ in 0..3 {
            c.on_window(k, 0.99, 0);
        }
        assert!(c.is_degraded(k));
        assert_eq!(c.on_window(k, 0.1, 0), DegradeAction::None);
        assert_eq!(c.on_window(k, 0.1, 0), DegradeAction::Probe);
        assert_eq!(c.on_probe_result(k, false), DegradeAction::None);
        assert!(c.is_degraded(k));
        // The calm count restarted: two more calm windows to re-probe.
        assert_eq!(c.on_window(k, 0.1, 0), DegradeAction::None);
        assert_eq!(c.on_window(k, 0.1, 0), DegradeAction::Probe);
        assert_eq!(c.on_probe_result(k, true), DegradeAction::Recover);
        assert_eq!(c.counts(k), (1, 1, 2));
    }
}
