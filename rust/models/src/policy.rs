//! Per-traffic-class codec policy (ISSUE 3).
//!
//! The paper compresses every traffic class with one codec (LEXI's
//! Huffman). With the [`ExpCodec`](lexi_core::codec::ExpCodec) layer the
//! codec becomes a per-[`TransferKind`] knob: SSM state vectors are small
//! and delta-local (a decent BDI fit with zero codebook startup), KV
//! cache and weights are frequency-concentrated (Huffman's home turf),
//! and `Raw` is the honest "don't touch it" point. `lexi-sim`'s `Engine`
//! carries a `CodecPolicy` so Table 3 can report mixed-codec operating
//! points; `lexi dse --what codec` sweeps them.

use crate::traffic::TransferKind;
use lexi_core::codec::CodecKind;

/// Which exponent codec each traffic class uses when a compression mode
/// compresses it at all (the mode still gates *whether* a kind is
/// compressed; the policy picks *how*).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CodecPolicy {
    pub weights: CodecKind,
    pub activation: CodecKind,
    pub kv_cache: CodecKind,
    pub ssm_state: CodecKind,
}

impl CodecPolicy {
    /// The same codec for every class.
    pub fn uniform(codec: CodecKind) -> Self {
        CodecPolicy {
            weights: codec,
            activation: codec,
            kv_cache: codec,
            ssm_state: codec,
        }
    }

    /// The paper's operating point: LEXI Huffman everywhere.
    pub fn lexi_default() -> Self {
        Self::uniform(CodecKind::Huffman)
    }

    /// A mixed hybrid-LLM point: BDI for the (delta-local, startup-
    /// sensitive) SSM state, Huffman for everything else.
    pub fn bdi_state() -> Self {
        CodecPolicy {
            ssm_state: CodecKind::Bdi,
            ..Self::lexi_default()
        }
    }

    /// The codec this policy assigns to `kind`.
    #[inline]
    pub fn codec_for(&self, kind: TransferKind) -> CodecKind {
        match kind {
            TransferKind::Weights => self.weights,
            TransferKind::Activation => self.activation,
            TransferKind::KvCache => self.kv_cache,
            TransferKind::SsmState => self.ssm_state,
        }
    }

    /// Reassign one class.
    pub fn set(&mut self, kind: TransferKind, codec: CodecKind) {
        match kind {
            TransferKind::Weights => self.weights = codec,
            TransferKind::Activation => self.activation = codec,
            TransferKind::KvCache => self.kv_cache = codec,
            TransferKind::SsmState => self.ssm_state = codec,
        }
    }

    /// Parse a CLI spec: a bare codec name applies uniformly
    /// (`huffman`), `bdi-state` is the mixed preset, and
    /// `kind=codec,...` pairs override the default per class
    /// (`ssm=bdi,kv=huffman`).
    pub fn parse(spec: &str) -> Result<Self, String> {
        match spec {
            "lexi" | "default" => return Ok(Self::lexi_default()),
            "bdi-state" => return Ok(Self::bdi_state()),
            _ => {}
        }
        if let Ok(codec) = CodecKind::parse(spec) {
            return Ok(Self::uniform(codec));
        }
        let mut policy = Self::lexi_default();
        for part in spec.split(',') {
            let (kind_s, codec_s) = part
                .split_once('=')
                .ok_or_else(|| format!("bad policy entry '{part}' (want kind=codec)"))?;
            let kind = match kind_s {
                "weights" | "w" => TransferKind::Weights,
                "act" | "activation" => TransferKind::Activation,
                "kv" | "kvcache" => TransferKind::KvCache,
                "ssm" | "state" => TransferKind::SsmState,
                other => return Err(format!("unknown traffic kind '{other}'")),
            };
            let codec = CodecKind::parse(codec_s).map_err(|e| e.to_string())?;
            policy.set(kind, codec);
        }
        Ok(policy)
    }

    /// Compact human-readable form (`w=huffman act=huffman kv=huffman
    /// ssm=bdi`).
    pub fn describe(&self) -> String {
        format!(
            "w={} act={} kv={} ssm={}",
            self.weights.name(),
            self.activation.name(),
            self.kv_cache.name(),
            self.ssm_state.name()
        )
    }
}

impl Default for CodecPolicy {
    fn default() -> Self {
        Self::lexi_default()
    }
}

/// Graceful-degradation knob (ISSUE 6): when a traffic class keeps
/// failing to decode (CRC NACKs that survive the NoC's retry budget),
/// the engine stops compressing that class rather than stalling the
/// pipeline on retransmissions — lossless first, fast second, but never
/// wedged.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DegradePolicy {
    /// Decode failures a single [`TransferKind`] may accumulate before
    /// its codec falls back to [`CodecKind::Raw`].
    pub failure_threshold: u32,
}

impl DegradePolicy {
    /// Paper-point default: three strikes per traffic class.
    pub fn paper_default() -> Self {
        DegradePolicy { failure_threshold: 3 }
    }
}

impl Default for DegradePolicy {
    fn default() -> Self {
        Self::paper_default()
    }
}

/// Per-traffic-class decode-failure accounting that drives
/// [`DegradePolicy`]. Indexed by [`TransferKind::ALL`] order.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct DegradeTracker {
    failures: [u32; 4],
    degraded: [bool; 4],
}

#[inline]
fn kind_index(kind: TransferKind) -> usize {
    match kind {
        TransferKind::Weights => 0,
        TransferKind::Activation => 1,
        TransferKind::KvCache => 2,
        TransferKind::SsmState => 3,
    }
}

impl DegradeTracker {
    /// A tracker with no failures recorded.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one decode failure for `kind`. Once the count reaches
    /// `policy.failure_threshold`, the class is marked degraded and its
    /// entry in `codec_policy` is rewritten to `Raw` (idempotent — later
    /// failures keep it Raw). Returns `true` iff this call flipped the
    /// class.
    pub fn record_failure(
        &mut self,
        kind: TransferKind,
        policy: DegradePolicy,
        codec_policy: &mut CodecPolicy,
    ) -> bool {
        let i = kind_index(kind);
        self.failures[i] = self.failures[i].saturating_add(1);
        if self.degraded[i] || self.failures[i] < policy.failure_threshold {
            return false;
        }
        self.degraded[i] = true;
        codec_policy.set(kind, CodecKind::Raw);
        true
    }

    /// Decode failures recorded for `kind`.
    pub fn failures(&self, kind: TransferKind) -> u32 {
        self.failures[kind_index(kind)]
    }

    /// Has `kind` been degraded to `Raw`?
    pub fn is_degraded(&self, kind: TransferKind) -> bool {
        self.degraded[kind_index(kind)]
    }

    /// Every degraded traffic class, [`TransferKind::ALL`] order.
    pub fn degraded_kinds(&self) -> Vec<TransferKind> {
        TransferKind::ALL
            .into_iter()
            .filter(|&k| self.is_degraded(k))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_all_huffman() {
        let p = CodecPolicy::default();
        for kind in TransferKind::ALL {
            assert_eq!(p.codec_for(kind), CodecKind::Huffman);
        }
    }

    #[test]
    fn bdi_state_only_touches_ssm() {
        let p = CodecPolicy::bdi_state();
        assert_eq!(p.codec_for(TransferKind::SsmState), CodecKind::Bdi);
        assert_eq!(p.codec_for(TransferKind::KvCache), CodecKind::Huffman);
        assert_eq!(p.codec_for(TransferKind::Weights), CodecKind::Huffman);
    }

    #[test]
    fn parse_specs() {
        assert_eq!(
            CodecPolicy::parse("bdi").unwrap(),
            CodecPolicy::uniform(CodecKind::Bdi)
        );
        assert_eq!(CodecPolicy::parse("bdi-state").unwrap(), CodecPolicy::bdi_state());
        let p = CodecPolicy::parse("ssm=bdi,kv=raw").unwrap();
        assert_eq!(p.codec_for(TransferKind::SsmState), CodecKind::Bdi);
        assert_eq!(p.codec_for(TransferKind::KvCache), CodecKind::Raw);
        assert_eq!(p.codec_for(TransferKind::Activation), CodecKind::Huffman);
        assert!(CodecPolicy::parse("zstd").is_err());
        assert!(CodecPolicy::parse("kv:bdi").is_err());
    }

    #[test]
    fn set_and_describe() {
        let mut p = CodecPolicy::lexi_default();
        p.set(TransferKind::Weights, CodecKind::Raw);
        assert_eq!(p.codec_for(TransferKind::Weights), CodecKind::Raw);
        assert_eq!(p.describe(), "w=raw act=huffman kv=huffman ssm=huffman");
    }

    #[test]
    fn degrade_flips_to_raw_at_threshold_only() {
        let mut policy = CodecPolicy::lexi_default();
        let mut tracker = DegradeTracker::new();
        let dp = DegradePolicy::paper_default();
        assert!(!tracker.record_failure(TransferKind::Activation, dp, &mut policy));
        assert!(!tracker.record_failure(TransferKind::Activation, dp, &mut policy));
        assert_eq!(policy.codec_for(TransferKind::Activation), CodecKind::Huffman);
        assert!(!tracker.is_degraded(TransferKind::Activation));
        // Third strike flips it — and only it.
        assert!(tracker.record_failure(TransferKind::Activation, dp, &mut policy));
        assert_eq!(policy.codec_for(TransferKind::Activation), CodecKind::Raw);
        assert!(tracker.is_degraded(TransferKind::Activation));
        assert_eq!(policy.codec_for(TransferKind::KvCache), CodecKind::Huffman);
        assert_eq!(tracker.degraded_kinds(), vec![TransferKind::Activation]);
        // Idempotent after the flip: more failures don't "re-flip".
        assert!(!tracker.record_failure(TransferKind::Activation, dp, &mut policy));
        assert_eq!(tracker.failures(TransferKind::Activation), 4);
    }

    #[test]
    fn degrade_threshold_one_is_immediate() {
        let mut policy = CodecPolicy::lexi_default();
        let mut tracker = DegradeTracker::new();
        let dp = DegradePolicy { failure_threshold: 1 };
        assert!(tracker.record_failure(TransferKind::SsmState, dp, &mut policy));
        assert_eq!(policy.codec_for(TransferKind::SsmState), CodecKind::Raw);
        assert_eq!(policy.codec_for(TransferKind::Weights), CodecKind::Huffman);
    }
}
