//! Synthetic activation / hybrid-cache exponent streams (paper-scale).
//!
//! At tiny scale the real model's tensors come from the PJRT runtime; at
//! paper scale we synthesize streams whose exponent statistics mirror what
//! the paper profiles: layer-norm keeps activations in a bounded band
//! (σ ≈ 1), KV caches follow the post-projection scale, SSM states sit
//! slightly wider. Each stream kind gets a distinct, layer-dependent σ so
//! per-layer codebooks (the paper's locality argument) actually matter.

use crate::config::ModelConfig;
use crate::traffic::TransferKind;
use lexi_core::prng::Rng;
use lexi_core::Bf16;

/// Synthesize `n` exponent bytes for a given transfer kind at `layer`.
pub fn sample_exponents(
    cfg: &ModelConfig,
    layer: usize,
    kind: TransferKind,
    seed: u64,
    n: usize,
) -> Vec<u8> {
    let mut rng = Rng::new(
        seed ^ (layer as u64).wrapping_mul(0x517cc1b727220a95) ^ kind_tag(kind),
    );
    let sigma = sigma_for(cfg, layer, kind);
    (0..n)
        .map(|_| Bf16::from_f32((rng.normal() * sigma) as f32).exponent())
        .collect()
}

/// The σ model: activations ≈ 1 (layer-norm bounded, slight depth drift),
/// KV ≈ 0.7, SSM state ≈ 1.6 (recurrent accumulation), weights-like for
/// anything else.
fn sigma_for(cfg: &ModelConfig, layer: usize, kind: TransferKind) -> f64 {
    let depth_drift = 1.0 + 0.02 * layer as f64;
    match kind {
        TransferKind::Activation => 1.0 * depth_drift,
        TransferKind::KvCache => 0.7 * depth_drift,
        TransferKind::SsmState => 1.6 * depth_drift,
        TransferKind::Weights => 1.0 / (cfg.d_model as f64).sqrt(),
    }
}

fn kind_tag(kind: TransferKind) -> u64 {
    match kind {
        TransferKind::Weights => 0x1111,
        TransferKind::Activation => 0x2222,
        TransferKind::KvCache => 0x3333,
        TransferKind::SsmState => 0x4444,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelScale;
    use lexi_core::stats::Histogram;

    #[test]
    fn activations_have_low_exponent_entropy() {
        let cfg = ModelConfig::jamba(ModelScale::Paper);
        for kind in [
            TransferKind::Activation,
            TransferKind::KvCache,
            TransferKind::SsmState,
        ] {
            let e = sample_exponents(&cfg, 2, kind, 11, 200_000);
            let h = Histogram::from_bytes(&e);
            assert!(h.entropy_bits() < 3.6, "{kind:?}: {}", h.entropy_bits());
        }
    }

    #[test]
    fn per_layer_distributions_differ() {
        let cfg = ModelConfig::qwen(ModelScale::Paper);
        let a = sample_exponents(&cfg, 0, TransferKind::Activation, 5, 64);
        let b = sample_exponents(&cfg, 10, TransferKind::Activation, 5, 64);
        assert_ne!(a, b);
    }

    #[test]
    fn deterministic() {
        let cfg = ModelConfig::qwen(ModelScale::Paper);
        let a = sample_exponents(&cfg, 3, TransferKind::KvCache, 9, 256);
        let b = sample_exponents(&cfg, 3, TransferKind::KvCache, 9, 256);
        assert_eq!(a, b);
    }
}
