//! # lexi-bench — minimal benchmark harness and table rendering
//!
//! `criterion` is not in the offline crate set, so the paper-reproduction
//! benches use this harness: warmup + repeated timed runs with
//! min/median/mean/max statistics, plus markdown table rendering shared
//! by the benches and the CLI (every table/figure regenerator prints the
//! same row layout the paper uses).

use std::time::{Duration, Instant};

/// Result of timing one benchmark case.
#[derive(Clone, Debug)]
pub struct Timing {
    pub name: String,
    pub runs: Vec<Duration>,
}

impl Timing {
    /// Fastest run.
    pub fn min(&self) -> Duration {
        self.runs.iter().min().copied().unwrap_or_default()
    }

    /// Slowest run.
    pub fn max(&self) -> Duration {
        self.runs.iter().max().copied().unwrap_or_default()
    }

    /// Median run.
    pub fn median(&self) -> Duration {
        let mut v = self.runs.clone();
        v.sort();
        v.get(v.len() / 2).copied().unwrap_or_default()
    }

    /// Mean run.
    pub fn mean(&self) -> Duration {
        if self.runs.is_empty() {
            return Duration::ZERO;
        }
        self.runs.iter().sum::<Duration>() / self.runs.len() as u32
    }

    /// Throughput for `items` processed per run.
    pub fn throughput(&self, items: u64) -> f64 {
        let s = self.median().as_secs_f64();
        if s == 0.0 {
            f64::INFINITY
        } else {
            items as f64 / s
        }
    }
}

/// Time `f` with `warmup` unmeasured and `runs` measured iterations.
pub fn bench<T>(name: &str, warmup: usize, runs: usize, mut f: impl FnMut() -> T) -> Timing {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut timings = Vec::with_capacity(runs);
    for _ in 0..runs {
        let t0 = Instant::now();
        std::hint::black_box(f());
        timings.push(t0.elapsed());
    }
    Timing {
        name: name.to_string(),
        runs: timings,
    }
}

/// A markdown-ish table builder with right-aligned numeric columns.
#[derive(Clone, Debug, Default)]
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// Start a table with the given column headers.
    pub fn new(headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (stringified cells).
    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells);
        self
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let cols = self.headers.len();
        let mut width = vec![0usize; cols];
        for (i, h) in self.headers.iter().enumerate() {
            width[i] = h.chars().count();
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                width[i] = width[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], width: &[usize]| -> String {
            let mut line = String::from("|");
            for (c, w) in cells.iter().zip(width) {
                line.push_str(&format!(" {:>w$} |", c, w = w));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers, &width));
        out.push('|');
        for w in &width {
            out.push_str(&format!("{:-<w$}|", "", w = w + 2));
        }
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r, &width));
        }
        out
    }

    /// Print to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a nanosecond count human-readably.
pub fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.2} s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.2} ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.2} µs", ns / 1e3)
    } else {
        format!("{ns:.0} ns")
    }
}

/// Format a ratio like the paper's tables (`3.14×`).
pub fn fmt_ratio(r: f64) -> String {
    format!("{r:.2}×")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_stats() {
        let t = bench("x", 1, 10, || std::hint::black_box(1 + 1));
        assert_eq!(t.runs.len(), 10);
        assert!(t.min() <= t.median());
        assert!(t.median() <= t.max());
    }

    #[test]
    fn table_render_aligns() {
        let mut t = Table::new(&["name", "value"]);
        t.row(vec!["a".into(), "1.0".into()]);
        t.row(vec!["long-name".into(), "123.45".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines.iter().all(|l| l.len() == lines[0].len()));
    }

    #[test]
    fn format_helpers() {
        assert_eq!(fmt_ns(1500.0), "1.50 µs");
        assert_eq!(fmt_ns(2.5e9), "2.50 s");
        assert_eq!(fmt_ratio(3.14159), "3.14×");
    }

    #[test]
    #[should_panic(expected = "column count mismatch")]
    fn row_width_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}
