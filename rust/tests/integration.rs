//! Cross-crate integration tests: software codec ↔ hardware model ↔
//! flit framing ↔ chiplet engine, plus failure injection.
//!
//! Runtime-dependent paths (PJRT + artifacts) live in `runtime_e2e.rs`
//! and skip gracefully when artifacts are absent.

use lexi::core::batch::{LaneCodec, LaneStream, LANE_CRC_ESCAPE};
use lexi::core::bf16::FieldStreams;
use lexi::core::bitstream::{BitReader, BitWriter};
use lexi::core::error::Error;
use lexi::core::flit::{self, FlitFormat};
use lexi::core::huffman::{self, CodeBook};
use lexi::core::proptest::check;
use lexi::core::stats::Histogram;
use lexi::core::Bf16;
use lexi::core::codec::CodecKind;
use lexi::hw::compressor::{Compressor, CompressorConfig};
use lexi::hw::decoder::{DecoderConfig, DecoderUnit, MultiLutSpec};
use lexi::hw::tree_builder;
use lexi::models::activations;
use lexi::models::corpus::Corpus;
use lexi::models::traffic::{self, TransferKind};
use lexi::models::{ModelConfig, ModelScale};
use lexi::noc::traffic::{segment_transfer_tagged, MAX_PACKET_BITS};
use lexi::noc::{CodecTag, EgressCodecConfig, FaultModel, Network, NetworkConfig, PacketSpec};
use lexi::sim::compression::{CompressionMode, CrTable};
use lexi::sim::engine::Engine;

/// HW compressor output decodes through the HW multi-stage decoder and
/// reproduces the input exactly — the full egress→ingress path.
#[test]
fn hw_egress_to_hw_ingress_lossless() {
    check("hw egress->ingress lossless", 25, |g| {
        let n = g.usize(1..4000);
        let data: Vec<u8> = g.vec(n, |g| {
            if g.bool(0.95) {
                110 + (g.usize(0..20) as u8)
            } else {
                g.u8()
            }
        });
        let comp = Compressor::new(CompressorConfig::paper_default());
        let (book, payload, report) = comp.compress(&data).unwrap();
        let unit = DecoderUnit::new(DecoderConfig::paper_default()).unwrap();
        let mut r = BitReader::with_len(&payload, report.payload_bits as usize);
        let (out, dec_report) = unit.decode(&mut r, &book, data.len()).unwrap();
        assert_eq!(out, data);
        assert_eq!(dec_report.symbols as usize, data.len());
    });
}

/// The HW-built codebook and the SW package-merge codebook agree on total
/// compressed cost for realistic streams (both are optimal prefix codes).
#[test]
fn hw_and_sw_codebooks_equal_cost() {
    let cfg = ModelConfig::jamba(ModelScale::Paper);
    for layer in 0..cfg.blocks.len() {
        for kind in [TransferKind::Activation, TransferKind::KvCache] {
            let exps = activations::sample_exponents(&cfg, layer, kind, 3, 20_000);
            let hist = Histogram::from_bytes(&exps);
            let sw = CodeBook::lexi_default(&hist).unwrap();
            let hw = tree_builder::build_codebook(&hist, 32).unwrap().book;
            assert_eq!(
                sw.payload_bits(&hist),
                hw.payload_bits(&hist),
                "layer {layer} {kind:?}"
            );
        }
    }
}

/// Field streams → flits → NoC → unpack: the payload a destination chiplet
/// reassembles is bit-identical to what the source emitted — and, since
/// ISSUE 5, the packets travel **codec-tagged** through the egress
/// decoder port, whose delivered symbol count and stall cycles must match
/// the `DecoderUnit` hardware model's predictions.
#[test]
fn flits_survive_the_network() {
    let mut rng = lexi::core::prng::Rng::new(9);
    let values: Vec<Bf16> = (0..5000)
        .map(|_| Bf16::from_f32(rng.normal_with(0.0, 1.0) as f32))
        .collect();
    let streams = FieldStreams::split(&values);
    let hist = Histogram::from_bytes(&streams.exponents);
    let book = CodeBook::lexi_default(&hist).unwrap();
    let format = FlitFormat::new(128).unwrap();
    let transfer = flit::pack(&streams, &book, format).unwrap();

    // Ship the same number of bits over the mesh, codec-tagged, through
    // an egress port whose Huffman rate is the measured multi-symbol LUT
    // unit for this very codebook.
    let ncfg = NetworkConfig::paper_default();
    let unit = DecoderUnit::with_multi(DecoderConfig::paper_default(), MultiLutSpec::paper_default())
        .unwrap();
    let lanes = 16usize;
    let ecfg = EgressCodecConfig::from_decoder(&unit, &book, lanes, 1.0);
    let tag = CodecTag {
        kind: CodecKind::Huffman,
        symbols: values.len() as u64,
        runtime_book: true,
    };
    let specs = segment_transfer_tagged(
        lexi::noc::NodeId(1),
        lexi::noc::NodeId(34),
        transfer.wire_bits(),
        0,
        MAX_PACKET_BITS,
        tag,
    );
    let mut net = Network::with_egress(ncfg, ecfg);
    net.schedule_packets(&specs);
    let stats = net.run_to_completion(10_000_000);
    assert_eq!(
        stats.delivered_flits as u64,
        specs.iter().map(|s| s.flits(ncfg.flit_bits) as u64).sum::<u64>()
    );
    // Every tagged exponent symbol was accounted for at the egress port.
    assert_eq!(stats.delivered_symbols, values.len() as u64);

    // Decode-stall prediction from the hw model: at 16 lanes the
    // measured LUT rate sustains line rate, so the only stalls are the
    // runtime codebook startup on the first packet's head flits.
    let cycle_ns = ncfg.cycle_ns();
    let drain_cycles_per_flit = {
        let total_flits: u64 =
            specs.iter().map(|s| s.flits(ncfg.flit_bits) as u64).sum();
        values.len() as f64 * unit.cycles_per_symbol(&book) / lanes as f64
            / total_flits as f64
            / cycle_ns
    };
    assert!(
        drain_cycles_per_flit < 1.0,
        "16 measured lanes must sustain line rate ({drain_cycles_per_flit})"
    );
    let startup_cycles = (ecfg.startup_ns / cycle_ns).ceil() as u64;
    assert!(
        stats.decode_stall_cycles >= startup_cycles.saturating_sub(2)
            && stats.decode_stall_cycles <= startup_cycles + 2,
        "stalls {} vs predicted startup {}",
        stats.decode_stall_cycles,
        startup_cycles
    );

    // A starved single-lane port is decode-bound: completion stretches
    // to ~the DecoderUnit makespan for these symbols.
    let mut net1 = Network::with_egress(ncfg, EgressCodecConfig::from_decoder(&unit, &book, 1, 1.0));
    net1.schedule_packets(&specs);
    let stats1 = net1.run_to_completion(10_000_000);
    let predicted_decode =
        values.len() as f64 * unit.cycles_per_symbol(&book) / cycle_ns;
    assert!(
        stats1.decode_stall_cycles > stats.decode_stall_cycles,
        "1-lane egress must stall more than 16-lane"
    );
    assert!(
        stats1.completion_cycle as f64 >= predicted_decode,
        "completion {} below the hw decode bound {predicted_decode}",
        stats1.completion_cycle
    );

    // And the flit payload itself unpacks losslessly.
    assert_eq!(flit::unpack(&transfer).unwrap().join(), values);
}

/// Hostile codec tags are rejected at scheduling — never mis-charged to
/// the egress decoder model.
#[test]
fn bogus_codec_tags_rejected_not_mischarged() {
    let ncfg = NetworkConfig::paper_default();
    let mut net = Network::with_egress(ncfg, EgressCodecConfig::paper_default());
    // More symbols than wire bits: physically impossible (every coded
    // symbol costs ≥ 1 bit on the wire).
    let over = PacketSpec::new(lexi::noc::NodeId(0), lexi::noc::NodeId(7), 1024, 0).tagged(
        CodecTag {
            kind: CodecKind::Huffman,
            symbols: 1025,
            runtime_book: true,
        },
    );
    assert!(net.try_schedule_packets(&[over]).is_err());
    // Tag riding a zero-size packet.
    let phantom = PacketSpec::new(lexi::noc::NodeId(0), lexi::noc::NodeId(7), 0, 0).tagged(
        CodecTag {
            kind: CodecKind::Raw,
            symbols: 1,
            runtime_book: false,
        },
    );
    assert!(net.try_schedule_packets(&[phantom]).is_err());
    // Nothing entered the network: no packets, no symbols, no stalls.
    let stats = net.run_to_completion(10);
    assert_eq!(stats.delivered_packets, 0);
    assert_eq!(stats.delivered_symbols, 0);
    assert_eq!(stats.decode_stall_cycles, 0);
    // A maximal-but-legal tag still schedules.
    let legal = PacketSpec::new(lexi::noc::NodeId(0), lexi::noc::NodeId(7), 1024, 0).tagged(
        CodecTag {
            kind: CodecKind::Huffman,
            symbols: 1024,
            runtime_book: true,
        },
    );
    assert!(net.try_schedule_packets(&[legal]).is_ok());
    let stats = net.run_to_completion(100_000);
    assert_eq!(stats.delivered_symbols, 1024);
}

/// Corrupted flits are rejected, not mis-decoded: flip bits in a packed
/// transfer and require either an error or a value mismatch to be
/// *detected* by count checks — silent success with wrong payload length
/// is the only unacceptable outcome.
#[test]
fn corrupted_flits_do_not_silently_pass() {
    check("flit corruption detected or contained", 40, |g| {
        let n = g.usize(64..800);
        let values: Vec<Bf16> = g.vec(n, |g| Bf16(g.u16()));
        let streams = FieldStreams::split(&values);
        let hist = Histogram::from_bytes(&streams.exponents);
        let book = CodeBook::lexi_default(&hist).unwrap();
        let format = FlitFormat::new(128).unwrap();
        let mut transfer = flit::pack(&streams, &book, format).unwrap();
        // Corrupt one random byte of one random data flit.
        let fi = g.usize(transfer.codebook_flits..transfer.flits.len());
        let bi = g.usize(0..transfer.flits[fi].bytes.len());
        let mask = (g.u8() | 1) as u8;
        transfer.flits[fi].bytes[bi] ^= mask;
        match flit::unpack(&transfer) {
            Err(_) => {}
            Ok(out) => {
                // A decode that "succeeds" must still have produced the
                // advertised value count; payload differences are fine at
                // this layer. Bit-level integrity is owned by the v3
                // checksummed `LaneStream` (per-lane CRC-16, ISSUE 6) and
                // the link-level retry in `lexi::noc` — see
                // `faulty_links_recover_and_checksums_catch_what_escapes`.
                assert_eq!(out.len(), values.len());
            }
        }
    });
}

/// The full ISSUE 6 fault story end to end: a checksummed v3
/// `LaneStream` crosses a mesh whose links corrupt flits at a seeded
/// BER. The link-level retry delivers every packet losslessly or
/// reports the drop — faults cost latency, never correctness, and the
/// run replays bit-identically from its seed. Whatever containment the
/// NoC could miss is caught one layer up by the per-lane CRC-16: a
/// flipped payload or header bit decodes to `Error::Corrupt`, never to
/// wrong symbols.
#[test]
fn faulty_links_recover_and_checksums_catch_what_escapes() {
    // A realistic skewed exponent stream, v3-encoded with checksums.
    let mut rng = lexi::core::prng::Rng::new(0x6_FA17);
    let exps: Vec<u8> = (0..64_000)
        .map(|_| {
            if rng.chance(0.9) {
                110 + rng.below(20) as u8
            } else {
                rng.next_u64() as u8
            }
        })
        .collect();
    let hist = Histogram::from_bytes(&exps);
    let book = CodeBook::lexi_default(&hist).unwrap();
    let codec = LaneCodec::new(4).unwrap().with_checksums();
    let stream = codec.encode(&exps, &book);
    assert_eq!(stream.bytes[0], LANE_CRC_ESCAPE);
    assert_eq!(stream.lane_crc.len(), 4);

    // Clean v3 round-trips on both decode paths, including a reparse
    // from raw wire bytes (the receiver's view).
    assert_eq!(LaneCodec::decode(&stream, &book).unwrap(), exps);
    assert_eq!(LaneCodec::decode_lockstep(&stream, &book).unwrap(), exps);
    let reparsed = LaneStream::from_bytes(stream.bytes.clone()).unwrap();
    assert_eq!(LaneCodec::decode(&reparsed, &book).unwrap(), exps);

    // Ship the wire bytes across the full mesh diagonal, fault-free
    // first as the latency baseline. 2-KiB packets (16 flits) keep the
    // seeded fault statistics robust: ~100 packets × 160 link
    // traversals at BER 1e-5 make zero injected corruptions and
    // budget-exhaustion floods both vanishingly unlikely.
    let ncfg = NetworkConfig::paper_default();
    let tag = CodecTag {
        kind: CodecKind::Huffman,
        symbols: exps.len() as u64,
        runtime_book: false,
    };
    let specs = segment_transfer_tagged(
        lexi::noc::NodeId(0),
        lexi::noc::NodeId(35),
        stream.bytes.len() as u64 * 8,
        0,
        2048,
        tag,
    );
    let n = specs.len() as u64;
    let mut clean_net = Network::new(ncfg);
    clean_net.schedule_packets(&specs);
    let clean = clean_net.run_to_completion(10_000_000);
    assert_eq!(clean.delivered_packets, n);
    assert_eq!(clean.delivered_symbols, exps.len() as u64);

    let fault = FaultModel::new(0xBE5).with_ber(1e-5);
    let run = |f: FaultModel| {
        let mut net = Network::with_faults(ncfg, f);
        net.schedule_packets(&specs);
        net.run_to_completion(10_000_000)
    };
    let stats = run(fault.clone());
    // Deterministic replay from the same seed.
    assert_eq!(stats, run(fault));
    // Exactly-once delivery or an explicitly reported drop — never
    // silence, never a hang.
    assert_eq!(stats.delivered_packets + stats.packets_dropped, n);
    assert!(stats.flits_corrupted > 0, "seeded BER run injected nothing");
    assert!(stats.packet_retries > 0, "corruption must trigger retransmission");
    assert_eq!(
        stats.link_faults.iter().sum::<u64>(),
        stats.flits_corrupted + stats.flits_dropped + stats.flits_duplicated
    );
    // Symbol accounting stays exact: delivered packets carry their full
    // tagged share, dropped packets contribute nothing.
    if stats.packets_dropped == 0 {
        assert_eq!(stats.delivered_symbols, exps.len() as u64);
    } else {
        assert!(stats.delivered_symbols < exps.len() as u64);
    }
    // Retry backoff + repeat trips are charged to latency.
    assert!(
        stats.avg_latency() >= clean.avg_latency(),
        "faulty links cannot beat ideal links: {} < {}",
        stats.avg_latency(),
        clean.avg_latency()
    );

    // Lossy (dropping) links deliver everything via link-level ARQ —
    // the flit retries at the FIFO head, so a wormhole body can never
    // vanish mid-packet.
    let lossy = run(FaultModel::new(0x10_55).with_drop(0.05));
    assert_eq!(lossy.delivered_packets, n);
    assert_eq!(lossy.delivered_symbols, exps.len() as u64);
    assert!(lossy.flits_dropped > 0, "seeded drop run injected nothing");
    assert!(lossy.avg_latency() >= clean.avg_latency());

    // An escaped flip — corruption the NoC's containment never saw —
    // is still caught by the stream CRCs, on both decode paths.
    let hb = stream.header_bytes();
    let mut dirty = stream.clone();
    dirty.bytes[hb] ^= 0x10; // first payload byte: lane 0
    assert!(matches!(
        LaneCodec::decode(&dirty, &book),
        Err(Error::Corrupt { block: 0, lane: 0 })
    ));
    assert!(matches!(
        LaneCodec::decode_lockstep(&dirty, &book),
        Err(Error::Corrupt { block: 0, lane: 0 })
    ));
    // A header flip (count field) dies at parse, before any payload
    // range — or book header — is trusted.
    let mut bad_header = stream.bytes.clone();
    bad_header[2] ^= 0x01;
    assert!(matches!(
        LaneStream::from_bytes(bad_header),
        Err(Error::Corrupt { block: 0, lane: 0 })
    ));
}

/// Truncated compressed blocks error out cleanly.
#[test]
fn truncated_blocks_error() {
    let data: Vec<u8> = (0..500u32).map(|i| 120 + (i % 9) as u8).collect();
    let block = huffman::compress_exponents(&data).unwrap();
    for cut in [1usize, 8, 64, block.bits / 2] {
        let mut short = block.clone();
        short.bits = short.bits.saturating_sub(cut);
        short.bytes.truncate(short.bits.div_ceil(8));
        assert!(
            huffman::decompress_exponents(&short).is_err(),
            "cut {cut} must not decode"
        );
    }
}

/// End-to-end (analytic): the Table-3 orderings hold for every model ×
/// dataset × mode combination simultaneously.
#[test]
fn mode_ordering_is_total() {
    let engine = Engine::paper_default();
    for cfg in ModelConfig::paper_models() {
        let crs = CrTable::measure(&cfg, 7);
        for corpus in Corpus::all() {
            let unc = engine.run(&cfg, &corpus, CompressionMode::Uncompressed, &crs);
            let wo = engine.run(&cfg, &corpus, CompressionMode::WeightsOnly, &crs);
            let lexi = engine.run(&cfg, &corpus, CompressionMode::Lexi, &crs);
            assert!(lexi.comm_ns < wo.comm_ns, "{} {}", cfg.name, corpus.name);
            assert!(wo.comm_ns <= unc.comm_ns, "{} {}", cfg.name, corpus.name);
            // Compute identical across modes (paper §5.3).
            assert_eq!(unc.compute_ns, lexi.compute_ns);
        }
    }
}

/// Weight-load traffic is once-per-inference: doubling output tokens must
/// not change it, while cache traffic grows.
#[test]
fn weight_traffic_is_one_time() {
    let cfg = ModelConfig::qwen(ModelScale::Paper);
    let short = Corpus {
        name: "short",
        input_tokens: 512,
        output_tokens: 64,
    };
    let long = Corpus {
        name: "long",
        input_tokens: 512,
        output_tokens: 128,
    };
    let vol = |c: &Corpus| traffic::volume_by_kind(&traffic::full_inference(&cfg, c));
    let vs = vol(&short);
    let vl = vol(&long);
    assert_eq!(
        vs[&TransferKind::Weights],
        vl[&TransferKind::Weights]
    );
    assert!(vl[&TransferKind::KvCache] > vs[&TransferKind::KvCache]);
}

/// The codec startup (sampling window + 81-cycle pipeline) is invisible at
/// layer scale: engine latency with and without the startup differs <1%.
#[test]
fn codec_startup_amortized_at_system_level() {
    let cfg = ModelConfig::zamba(ModelScale::Paper);
    let corpus = Corpus::wikitext2();
    let crs = CrTable::measure(&cfg, 7);
    let with = Engine::paper_default();
    let mut without = Engine::paper_default();
    without.codec_startup_ns = 0.0;
    without.lut_fill_cycles = 0.0; // ISSUE 4: the table refill amortizes too
    let a = with.run(&cfg, &corpus, CompressionMode::Lexi, &crs);
    let b = without.run(&cfg, &corpus, CompressionMode::Lexi, &crs);
    let delta = (a.comm_ns - b.comm_ns) / b.comm_ns;
    assert!(delta < 0.02, "startup adds {delta:.4} of comm time");
}
