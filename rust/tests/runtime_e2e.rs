//! Runtime integration: PJRT + AOT artifacts + coordinator.
//!
//! These tests need `artifacts/` (run `make artifacts`); they skip — not
//! fail — when it is absent, so `cargo test` works on a fresh checkout.

use lexi::coordinator::Session;
use lexi::models::corpus::Corpus;
use lexi::runtime::{Manifest, Runtime};
use lexi::sim::compression::CompressionMode;
use lexi::sim::engine::Engine;
use lexi::models::{ModelConfig, ModelScale};

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

#[test]
fn manifest_parses_and_is_consistent() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let m = Manifest::load(&dir).unwrap();
    for name in ["jamba", "zamba", "qwen"] {
        let mm = m.models.get(name).unwrap_or_else(|| panic!("{name} missing"));
        assert_eq!(mm.seq_in, 128);
        assert_eq!(mm.prefill.output_names[0], "logits");
        assert_eq!(mm.decode.inputs.len(), 5);
        assert!(dir.join(&mm.prefill.file).exists());
        assert!(dir.join(&mm.decode.file).exists());
    }
}

#[test]
fn coordinated_inference_profiles_real_streams() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let manifest = Manifest::load(&dir).unwrap();
    let rt = Runtime::cpu().unwrap();
    let loaded = rt.load_model(&manifest, "jamba").unwrap();
    let mm = loaded.manifest.clone();
    let corpus = Corpus::wikitext2();
    let tokens: Vec<i32> = corpus
        .tokens(mm.vocab, 11)
        .iter()
        .take(mm.seq_in)
        .map(|&t| t as i32)
        .collect();
    let session = Session::new(loaded);
    let report = session.run(&tokens, 4).unwrap();

    assert_eq!(report.generated.len(), 4);
    assert!(!report.profiles.is_empty());
    // The paper's core claims on REAL tensors:
    for p in &report.profiles {
        assert!(p.exp_entropy < 4.5, "{}: H {}", p.name, p.exp_entropy);
        assert!(p.mant_entropy > 6.0, "{}: Hm {}", p.name, p.mant_entropy);
        assert!(p.exp_distinct <= 40, "{}: {}", p.name, p.exp_distinct);
        assert!(p.lexi_cr > 1.8, "{}: cr {}", p.name, p.lexi_cr);
        assert!(p.rle_cr < 1.1, "{}: rle {}", p.name, p.rle_cr);
        assert!(p.wire_ratio > 1.2, "{}: wire {}", p.name, p.wire_ratio);
    }

    // Measured ratios drive the engine into the paper's reduction band.
    let crs = report.measured_cr_table();
    let engine = Engine::paper_default();
    let cfg = ModelConfig::jamba(ModelScale::Paper);
    let unc = engine.run(&cfg, &corpus, CompressionMode::Uncompressed, &crs);
    let lexi = engine.run(&cfg, &corpus, CompressionMode::Lexi, &crs);
    let red = 1.0 - lexi.comm_ns / unc.comm_ns;
    assert!((0.25..0.50).contains(&red), "comm reduction {red:.3}");
}

#[test]
fn decode_is_reproducible_across_sessions() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let manifest = Manifest::load(&dir).unwrap();
    let rt = Runtime::cpu().unwrap();
    let run = || {
        let loaded = rt.load_model(&manifest, "zamba").unwrap();
        let mm = loaded.manifest.clone();
        let tokens: Vec<i32> = (0..mm.seq_in as i32).map(|i| (i * 3) % mm.vocab as i32).collect();
        Session::new(loaded).run(&tokens, 3).unwrap().generated
    };
    assert_eq!(run(), run());
}
