//! Flit-aligned packetization (paper §4.1, §4.3).
//!
//! Inter-chiplet links move fixed-size flits, one per cycle. LEXI packs
//! compressed activations *flit-atomically*:
//!
//! ```text
//! { Header(count) | sign bits | mantissas | coded exponents | 0-pad }
//! ```
//!
//! The header says how many whole values the flit carries; values never
//! straddle flits, so the decoder can process each flit independently
//! (that is what lets the hardware fan flits out to parallel decode lanes
//! round-robin, §4.4). A layer transfer prepends a head section in
//! dedicated flits: a [`CODEC_TAG_BITS`]-bit **codec tag** (ISSUE 3: the
//! wire is self-describing, [`unpack`] dispatches on it), the serialized
//! codebook when the codec is Huffman, and the value count.
//!
//! Exponent sections are codec-dispatched per [`CodecKind`]:
//! * `Huffman` — batch-encoded codewords (bit-identical to the pre-trait
//!   packer);
//! * `Bdi` — a headerless [`bdi::encode_blocks`] stream over the flit's
//!   exponents (the flit count header already says how many);
//! * `Raw` — the exponent bytes verbatim.

use crate::batch::BatchEncoder;
use crate::bdi;
use crate::bf16::FieldStreams;
use crate::bitstream::{BitReader, BitWriter};
use crate::codec::{CodecKind, CODEC_TAG_BITS};
use crate::error::{Error, Result};
use crate::huffman::CodeBook;

/// A single fixed-size flit (its payload bytes).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Flit {
    pub bytes: Vec<u8>,
}

/// Packetizer configuration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FlitFormat {
    /// Flit width in bits (e.g. 128 for a 100 Gbps @ 1 GHz NoI link).
    pub flit_bits: u32,
    /// Header width in bits (value count per flit).
    pub header_bits: u32,
}

impl FlitFormat {
    /// Standard format for a given flit width: the header is sized to count
    /// the theoretical max values per flit (9 bits/value: 1 sign + 7
    /// mantissa + ≥1-bit exponent code).
    pub fn new(flit_bits: u32) -> Result<Self> {
        if flit_bits < 32 || flit_bits > 4096 {
            return Err(Error::InvalidParameter(format!(
                "flit width {flit_bits} out of supported range 32..=4096"
            )));
        }
        let max_vals = flit_bits / 9;
        let header_bits = 32 - (max_vals + 1).leading_zeros();
        Ok(FlitFormat {
            flit_bits,
            header_bits,
        })
    }

    /// Payload bits available for values.
    #[inline]
    pub fn payload_bits(&self) -> u32 {
        self.flit_bits - self.header_bits
    }

    /// Bits one value occupies given its exponent codeword length.
    #[inline]
    pub fn value_bits(&self, code_len: u32) -> u32 {
        1 + 7 + code_len
    }
}

/// A complete per-layer transfer: head flits followed by data flits.
#[derive(Clone, Debug)]
pub struct LayerTransfer {
    pub format: FlitFormat,
    /// Exponent codec the transfer was packed with. Informational: the
    /// authoritative copy is the wire tag in the head flits, which is
    /// what [`unpack`] dispatches on.
    pub codec: CodecKind,
    pub flits: Vec<Flit>,
    /// Number of leading flits that carry the codec tag + codebook header.
    pub codebook_flits: usize,
    /// Values packed.
    pub count: usize,
}

impl LayerTransfer {
    /// Total bits on the wire.
    pub fn wire_bits(&self) -> u64 {
        self.flits.len() as u64 * self.format.flit_bits as u64
    }

    /// Compression ratio vs sending raw BF16 in the same flit format
    /// (which also pays a per-flit header).
    pub fn ratio_vs_uncompressed(&self) -> f64 {
        uncompressed_flits(self.format, self.count) as f64 / self.flits.len() as f64
    }
}

/// Flits needed to send `count` raw BF16 values in the same framing.
pub fn uncompressed_flits(format: FlitFormat, count: usize) -> u64 {
    let per = (format.payload_bits() / 16).max(1) as u64;
    (count as u64).div_ceil(per)
}

/// Pack field streams into a layer transfer using `book` for exponents —
/// the LEXI (Huffman) path, byte-compatible with every existing caller.
pub fn pack(streams: &FieldStreams, book: &CodeBook, format: FlitFormat) -> Result<LayerTransfer> {
    pack_codec(streams, CodecKind::Huffman, Some(book), format)
}

/// Pack field streams with an explicit exponent codec (ISSUE 3). `book`
/// is required for [`CodecKind::Huffman`] and ignored otherwise.
pub fn pack_codec(
    streams: &FieldStreams,
    codec: CodecKind,
    book: Option<&CodeBook>,
    format: FlitFormat,
) -> Result<LayerTransfer> {
    let book = match (codec, book) {
        (CodecKind::Huffman, Some(b)) => Some(b),
        (CodecKind::Huffman, None) => {
            return Err(Error::InvalidParameter(
                "Huffman packing needs a codebook".into(),
            ))
        }
        _ => None,
    };
    let n = streams.len();
    // --- head flits: codec tag, codebook (Huffman only), count ----------
    let mut head = BitWriter::new();
    head.put(codec.wire_tag() as u64, CODEC_TAG_BITS);
    if let Some(book) = book {
        book.write_header(&mut head);
    }
    head.put(n as u64, 32);
    head.pad_to_multiple(format.flit_bits as usize);
    let head_bytes = head.into_bytes();
    let flit_bytes = (format.flit_bits as usize).div_ceil(8);
    let mut flits: Vec<Flit> = head_bytes
        .chunks(flit_bytes)
        .map(|c| {
            let mut b = c.to_vec();
            b.resize(flit_bytes, 0);
            Flit { bytes: b }
        })
        .collect();
    let codebook_flits = flits.len();

    // --- data flits (flit-atomic greedy fill) ---------------------------
    // §Perf: one pair-fused batch encoder for the whole transfer; the
    // greedy fill itself prices values off the packed `symbol_bits` LUT
    // (Huffman), `bdi::block_bits` (BDI), or the constant 16 bits (Raw).
    let enc = book.map(BatchEncoder::new);
    let mut i = 0usize;
    while i < n {
        // Greedily select how many values fit in this flit.
        let k = match codec {
            CodecKind::Huffman => {
                let book = book.expect("checked above");
                let mut used = 0u32;
                let mut k = 0usize;
                while i + k < n {
                    let bits =
                        format.value_bits(book.symbol_bits(streams.exponents[i + k]));
                    if used + bits > format.payload_bits() {
                        break;
                    }
                    used += bits;
                    k += 1;
                }
                k
            }
            CodecKind::Bdi => bdi_fill(&streams.exponents[i..], format),
            CodecKind::Raw => {
                ((format.payload_bits() / 16) as usize).min(n - i)
            }
        };
        if k == 0 {
            // A single value larger than the payload cannot happen with
            // sane formats (max Huffman value = 8 esc + 8 raw + 8 = 24,
            // max BDI/raw value = 8 + 11 = 19 … payload ≥ 32-header);
            // guard anyway.
            return Err(Error::MalformedFlit(format!(
                "value at {i} does not fit an empty flit"
            )));
        }
        let mut w = BitWriter::new();
        w.put(k as u64, format.header_bits);
        // §Perf: batch the fixed-width fields — one put for all sign bits
        // (k ≤ 56 for any supported flit), mantissas in groups of 8
        // (8 × 7 = 56 bits per put).
        for group in streams.signs[i..i + k].chunks(56) {
            let mut signword = 0u64;
            for &s in group {
                signword = (signword << 1) | (s & 1) as u64;
            }
            w.put(signword, group.len() as u32);
        }
        let mants = &streams.mantissas[i..i + k];
        for group in mants.chunks(8) {
            let mut word = 0u64;
            for &m in group {
                word = (word << 7) | (m & 0x7f) as u64;
            }
            w.put(word, 7 * group.len() as u32);
        }
        let exps = &streams.exponents[i..i + k];
        match codec {
            CodecKind::Huffman => {
                enc.as_ref().expect("checked above").encode_block(exps, &mut w)
            }
            CodecKind::Bdi => bdi::encode_blocks(exps, &mut w),
            CodecKind::Raw => {
                for group in exps.chunks(7) {
                    let mut word = 0u64;
                    for &e in group {
                        word = (word << 8) | e as u64;
                    }
                    w.put(word, 8 * group.len() as u32);
                }
            }
        }
        w.pad_to_multiple(format.flit_bits as usize);
        let mut bytes = w.into_bytes();
        bytes.resize(flit_bytes, 0);
        flits.push(Flit { bytes });
        i += k;
    }

    Ok(LayerTransfer {
        format,
        codec,
        flits,
        codebook_flits,
        count: n,
    })
}

/// Greedy fill for the BDI exponent section: grow `k` while
/// `k × (sign+mantissa) + bdi::stream_bits(exps[..k])` fits the payload.
/// Only the trailing partial block's cost changes per step, so the scan
/// is O(k · BLOCK) worst case — flits hold at most a few hundred values.
///
/// `k` is additionally capped at the count-header maximum: the header is
/// sized for ≥9 bits/value ([`FlitFormat::new`]), but BDI's amortized
/// floor is 8 + 11/32 ≈ 8.34 bits/value, so on some flit widths (e.g.
/// 560 bits, header max 63) a width-0 stream would otherwise overflow
/// the header field and corrupt everything after it.
fn bdi_fill(exps: &[u8], format: FlitFormat) -> usize {
    let kmax = (1usize << format.header_bits) - 1;
    let mut k = 0usize;
    let mut full_bits = 0usize; // completed 32-element blocks
    while k < exps.len() && k < kmax {
        let cand = k + 1;
        let block_start = (cand - 1) / bdi::BLOCK * bdi::BLOCK;
        let tail_bits = bdi::block_bits(&exps[block_start..cand]);
        let used = cand * 8 + full_bits + tail_bits;
        if used > format.payload_bits() as usize {
            break;
        }
        k = cand;
        if cand % bdi::BLOCK == 0 {
            full_bits += tail_bits;
        }
    }
    k
}

/// Unpack a layer transfer back into field streams. Lossless inverse of
/// [`pack`] / [`pack_codec`]: the codec is read from the wire tag, not
/// trusted from the struct.
pub fn unpack(transfer: &LayerTransfer) -> Result<FieldStreams> {
    let format = transfer.format;
    // --- head: codec tag, codebook, count --------------------------------
    let mut head_bytes = Vec::new();
    for f in &transfer.flits[..transfer.codebook_flits] {
        head_bytes.extend_from_slice(&f.bytes);
    }
    let mut r = BitReader::new(&head_bytes);
    let codec = CodecKind::from_wire_tag(r.get(CODEC_TAG_BITS)? as u8)?;
    let book = match codec {
        CodecKind::Huffman => Some(CodeBook::read_header(&mut r)?),
        _ => None,
    };
    let count = r.get(32)? as usize;
    // §Perf (ISSUE 4): one decoder serves every data flit of the
    // transfer, so a transfer long enough to amortize the table fill
    // decodes its per-flit exponent runs through the multi-symbol LUT.
    let dec = book.map(|b| b.decoder_for(count));

    // --- data flits --------------------------------------------------------
    let mut out = FieldStreams::default();
    for f in &transfer.flits[transfer.codebook_flits..] {
        let mut r = BitReader::with_len(&f.bytes, format.flit_bits as usize);
        let k = r.get(format.header_bits)? as usize;
        let base = out.signs.len();
        // §Perf: read the fixed-width fields in the same word-sized
        // groups `pack` wrote them (≤56 sign bits / 8×7 mantissa bits per
        // get), then batch-decode the exponent run in one pass.
        let mut got = 0usize;
        while got < k {
            let take = (k - got).min(56);
            let word = r.get(take as u32)?;
            for j in (0..take).rev() {
                out.signs.push(((word >> j) & 1) as u8);
            }
            got += take;
        }
        let mut got = 0usize;
        while got < k {
            let take = (k - got).min(8);
            let word = r.get(7 * take as u32)?;
            for j in (0..take).rev() {
                out.mantissas.push(((word >> (7 * j)) & 0x7f) as u8);
            }
            got += take;
        }
        let ebase = out.exponents.len();
        out.exponents.resize(ebase + k, 0);
        match &dec {
            Some(dec) => dec.decode_block_into(&mut r, &mut out.exponents[ebase..])?,
            None if codec == CodecKind::Bdi => {
                bdi::decode_blocks(&mut r, &mut out.exponents[ebase..])?
            }
            None => {
                let mut got = 0usize;
                while got < k {
                    let take = (k - got).min(7);
                    let word = r.get(8 * take as u32)?;
                    for j in (0..take).rev() {
                        out.exponents[ebase + got] = ((word >> (8 * j)) & 0xff) as u8;
                        got += 1;
                    }
                }
            }
        }
        debug_assert_eq!(out.signs.len(), base + k);
    }
    if out.len() != count {
        // The head flit's transfer count and the per-flit counts
        // disagree: the transfer was corrupted in flight (ISSUE 6) —
        // typed Corrupt, so callers can trigger retransmission instead
        // of treating it as a programming error.
        return Err(Error::Corrupt { block: 0, lane: 0 });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bf16::Bf16;
    use crate::prng::Rng;
    use crate::proptest::check;
    use crate::stats::Histogram;

    fn gaussian_values(n: usize, sigma: f64, seed: u64) -> Vec<Bf16> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| Bf16::from_f32(rng.normal_with(0.0, sigma) as f32))
            .collect()
    }

    fn book_for(streams: &FieldStreams) -> CodeBook {
        CodeBook::lexi_default(&Histogram::from_bytes(&streams.exponents)).unwrap()
    }

    #[test]
    fn format_header_sizing() {
        let f = FlitFormat::new(128).unwrap();
        // 128/9 = 14 values max → 4-bit header counts 0..15.
        assert_eq!(f.header_bits, 4);
        assert_eq!(f.payload_bits(), 124);
        assert!(FlitFormat::new(16).is_err());
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let vals = gaussian_values(5000, 0.02, 7);
        let streams = FieldStreams::split(&vals);
        let book = book_for(&streams);
        let format = FlitFormat::new(128).unwrap();
        let t = pack(&streams, &book, format).unwrap();
        assert_eq!(t.codec, CodecKind::Huffman);
        let back = unpack(&t).unwrap();
        assert_eq!(back, streams);
        assert_eq!(back.join(), vals);
    }

    #[test]
    fn pack_codec_roundtrips_every_backend() {
        let vals = gaussian_values(4000, 0.02, 13);
        let streams = FieldStreams::split(&vals);
        let book = book_for(&streams);
        let format = FlitFormat::new(128).unwrap();
        for codec in CodecKind::ALL {
            let t = pack_codec(&streams, codec, Some(&book), format).unwrap();
            assert_eq!(t.codec, codec);
            assert_eq!(unpack(&t).unwrap().join(), vals, "{codec:?}");
        }
        // Huffman without a book is an error, not a panic.
        assert!(pack_codec(&streams, CodecKind::Huffman, None, format).is_err());
    }

    #[test]
    fn codec_wire_ratios_order() {
        // On concentrated streams: Huffman > BDI > Raw ≈ 1.0 (raw pays
        // only the head flit, so it sits just under 1×).
        let vals = gaussian_values(30_000, 0.02, 5);
        let streams = FieldStreams::split(&vals);
        let book = book_for(&streams);
        let format = FlitFormat::new(128).unwrap();
        let ratio = |codec| {
            pack_codec(&streams, codec, Some(&book), format)
                .unwrap()
                .ratio_vs_uncompressed()
        };
        let h = ratio(CodecKind::Huffman);
        let b = ratio(CodecKind::Bdi);
        let r = ratio(CodecKind::Raw);
        assert!(h > b, "huffman {h} vs bdi {b}");
        assert!(b > 1.05, "bdi {b}");
        assert!((0.95..=1.0).contains(&r), "raw {r}");
    }

    #[test]
    fn bdi_fill_never_overflows_the_count_header() {
        // Regression (review finding): at flit widths where the
        // 9-bit/value header sizing meets BDI's ~8.34-bit/value floor
        // (560 bits → header max 63, but 65 width-0 values fit the
        // payload), the greedy fill must clamp to the header range.
        let vals: Vec<Bf16> = (0..1000)
            .map(|i| Bf16::from_fields((i % 2) as u8, 120, (i % 128) as u8))
            .collect();
        let streams = FieldStreams::split(&vals);
        for flit_bits in [560u32, 544, 576, 1096] {
            let format = FlitFormat::new(flit_bits).unwrap();
            let kmax = (1u64 << format.header_bits) - 1;
            let t = pack_codec(&streams, CodecKind::Bdi, None, format).unwrap();
            for f in &t.flits[t.codebook_flits..] {
                let mut r = BitReader::with_len(&f.bytes, format.flit_bits as usize);
                assert!(r.get(format.header_bits).unwrap() <= kmax);
            }
            assert_eq!(unpack(&t).unwrap().join(), vals, "{flit_bits}");
        }
    }

    #[test]
    fn unpack_dispatches_on_wire_tag_not_struct_field() {
        // Corrupt the struct-level codec field: unpack must still decode
        // correctly because the tag rides in the head flit bytes.
        let vals = gaussian_values(800, 0.02, 3);
        let streams = FieldStreams::split(&vals);
        let format = FlitFormat::new(128).unwrap();
        let mut t = pack_codec(&streams, CodecKind::Bdi, None, format).unwrap();
        t.codec = CodecKind::Huffman; // lie in the struct
        assert_eq!(unpack(&t).unwrap().join(), vals);
    }

    #[test]
    fn reserved_wire_tag_rejected() {
        let vals = gaussian_values(100, 0.02, 3);
        let streams = FieldStreams::split(&vals);
        let format = FlitFormat::new(128).unwrap();
        let mut t = pack_codec(&streams, CodecKind::Raw, None, format).unwrap();
        // Tag lives in the top CODEC_TAG_BITS of the first head byte;
        // force the reserved pattern 0b11.
        t.flits[0].bytes[0] |= 0b1100_0000;
        assert!(unpack(&t).is_err());
    }

    #[test]
    fn tampered_flit_counts_error_not_panic() {
        // ISSUE 6 audit: disagreements between the head flit's transfer
        // count and the per-flit counts must surface as a typed error
        // (Corrupt when the streams decode but the totals mismatch),
        // never a panic or a silently short output.
        let vals = gaussian_values(600, 0.02, 21);
        let streams = FieldStreams::split(&vals);
        let format = FlitFormat::new(128).unwrap();
        let t = pack_codec(&streams, CodecKind::Raw, None, format).unwrap();
        // Flip the transfer count's least-significant bit. Raw head
        // layout: 2-bit tag then count:32, so that is head bit 33 —
        // byte 4, second-from-MSB. Every data flit still decodes, so
        // the total/count mismatch is caught at the end as Corrupt.
        let mut fewer = t.clone();
        fewer.flits[0].bytes[4] ^= 1 << (7 - ((2 + 31) % 8));
        assert_eq!(
            unpack(&fewer).unwrap_err(),
            Error::Corrupt { block: 0, lane: 0 }
        );
        // Zero out a data flit's per-flit count: totals can no longer
        // match; must be a typed error.
        let mut short = t.clone();
        let last = short.flits.len() - 1;
        for b in &mut short.flits[last].bytes {
            *b = 0;
        }
        assert!(unpack(&short).is_err());
    }

    #[test]
    fn compression_beats_uncompressed_framing() {
        let vals = gaussian_values(20_000, 0.05, 11);
        let streams = FieldStreams::split(&vals);
        let book = book_for(&streams);
        let format = FlitFormat::new(128).unwrap();
        let t = pack(&streams, &book, format).unwrap();
        let ratio = t.ratio_vs_uncompressed();
        // Paper Fig 1c: 36–40% comm reduction ⇒ ratio ≈ 1.5–1.7; allow a
        // generous band since σ and framing overheads shift it.
        assert!(ratio > 1.25, "ratio {ratio}");
    }

    #[test]
    fn codebook_flits_counted() {
        let vals = gaussian_values(100, 0.02, 3);
        let streams = FieldStreams::split(&vals);
        let book = book_for(&streams);
        let format = FlitFormat::new(128).unwrap();
        let t = pack(&streams, &book, format).unwrap();
        assert!(t.codebook_flits >= 1);
        assert!(t.codebook_flits <= 4);
        // Book-less codecs need only the tag + count head flit.
        let raw = pack_codec(&streams, CodecKind::Raw, None, format).unwrap();
        assert_eq!(raw.codebook_flits, 1);
    }

    #[test]
    fn prop_roundtrip_any_bf16_any_codec() {
        check("flit roundtrip arbitrary bf16 × codec", 80, |g| {
            let n = g.usize(1..2000);
            let vals: Vec<Bf16> = g.vec(n, |g| Bf16(g.u16()));
            let streams = FieldStreams::split(&vals);
            let book = book_for(&streams);
            // 1024/2048-bit flits exceed the 56-bit sign-word batch and
            // exercise the chunked path.
            let flit_bits = [64u32, 128, 256, 1024, 2048][g.usize(0..5)];
            let format = FlitFormat::new(flit_bits).unwrap();
            let codec = CodecKind::ALL[g.usize(0..3)];
            let t = pack_codec(&streams, codec, Some(&book), format).unwrap();
            let back = unpack(&t).unwrap();
            assert_eq!(back.join(), vals);
        });
    }

    #[test]
    fn stale_codebook_still_lossless() {
        // Hardware builds the codebook from the first 512 samples only; the
        // rest go through it (possibly via ESC). Must stay lossless.
        check("stale codebook lossless", 40, |g| {
            let n = g.usize(600..4000);
            let vals: Vec<Bf16> = g.vec(n, |g| {
                // Distribution shift halfway through.
                let sigma = if g.bool(0.5) { 0.02 } else { 4.0 };
                Bf16::from_f32((g.normal() * sigma) as f32)
            });
            let streams = FieldStreams::split(&vals);
            let hist = Histogram::from_bytes(&streams.exponents[..512]);
            let book = CodeBook::lexi_default(&hist).unwrap();
            let format = FlitFormat::new(128).unwrap();
            let t = pack(&streams, &book, format).unwrap();
            assert_eq!(unpack(&t).unwrap().join(), vals);
        });
    }
}
