//! §Perf — dependency-free sharded thread pool (ISSUE 8).
//!
//! The parallel codec paths (`compress_exponents_par`, lane-parallel
//! decode, the `lexi-hw` batch model) all reduce to the same shape: `S`
//! independent shards, each a pure function of its index, results wanted
//! in shard order. This module runs that shape on scoped threads with
//! **no work stealing and no shared queues** — shard `s` is statically
//! owned by thread `⌊s·T/S⌋`'s contiguous range, so the set of shards a
//! thread runs (and therefore every byte each shard produces) is a pure
//! function of `(S, T)`, never of scheduling.
//!
//! Determinism contract (DESIGN.md §SIMD & sharded parallelism): the
//! returned `Vec` is in shard order and byte-identical for every thread
//! count, because shard *content* never depends on which thread ran it —
//! parallel callers must partition their input by fixed shard geometry
//! (e.g. `huffman::PAR_BLOCK_SYMBOLS`), not by `T`. `threads == 1` (and
//! any single-shard call) runs inline on the caller's thread with no
//! spawn at all.
//!
//! Same zero-dependency philosophy as the local `anyhow` shim: the
//! offline crate set has no `rayon`, and the codec doesn't need one —
//! `std::thread::scope` + `split_at_mut` is the whole machine.

/// Threads worth spawning on this machine (≥ 1; falls back to 1 where
/// the OS won't say). Benches and CLI paths use this as their default
/// `T`; library callers always pass `T` explicitly so results are
/// reproducible across machines.
pub fn max_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Run `f(0..shards)` across up to `threads` scoped threads and return
/// the results **in shard order**. Thread `t` owns the contiguous shard
/// range `⌊shards·t/T⌋ .. ⌊shards·(t+1)/T⌋` — no stealing, so outputs
/// are independent of scheduling and of `threads` itself. A panicking
/// shard propagates the panic to the caller (scoped join).
pub fn run_sharded<T, F>(shards: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if shards == 0 {
        return Vec::new();
    }
    let threads = threads.max(1).min(shards);
    if threads == 1 {
        return (0..shards).map(f).collect();
    }
    let mut slots: Vec<Option<T>> = (0..shards).map(|_| None).collect();
    std::thread::scope(|scope| {
        let f = &f;
        let mut rest = &mut slots[..];
        let mut lo = 0usize;
        for t in 0..threads {
            let hi = shards * (t + 1) / threads;
            let (chunk, tail) = rest.split_at_mut(hi - lo);
            rest = tail;
            let base = lo;
            scope.spawn(move || {
                for (i, slot) in chunk.iter_mut().enumerate() {
                    *slot = Some(f(base + i));
                }
            });
            lo = hi;
        }
    });
    slots
        .into_iter()
        .map(|s| s.expect("every shard range was spawned"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest::check;

    #[test]
    fn results_are_in_shard_order() {
        for threads in [1usize, 2, 3, 8, 64] {
            let got = run_sharded(13, threads, |s| s * s);
            let want: Vec<usize> = (0..13).map(|s| s * s).collect();
            assert_eq!(got, want, "threads {threads}");
        }
    }

    #[test]
    fn zero_and_one_shard_edges() {
        assert!(run_sharded(0, 8, |s| s).is_empty());
        assert_eq!(run_sharded(1, 8, |s| s + 41), vec![41]);
    }

    #[test]
    fn prop_thread_count_invariance() {
        // The determinism contract: identical results for every T,
        // including T > shards and T = 1 (inline path).
        check("run_sharded is T-invariant", 50, |g| {
            let shards = g.usize(1..40);
            let salt = g.u64(0..1 << 40);
            let run = |t: usize| {
                run_sharded(shards, t, |s| {
                    (s as u64).wrapping_mul(0x9e37_79b9).wrapping_add(salt)
                })
            };
            let base = run(1);
            for t in [2usize, 3, 8, 64] {
                assert_eq!(run(t), base, "threads {t}");
            }
        });
    }

    #[test]
    fn max_threads_is_positive() {
        assert!(max_threads() >= 1);
    }
}
