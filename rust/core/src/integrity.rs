//! Stream integrity: CRC-16 for the codec wire formats (ISSUE 6).
//!
//! LEXI's contract is *lossless* exponent transport, but a Huffman stream
//! has no redundancy of its own — a single flipped wire bit silently
//! decodes into wrong exponents. This module adds the detection half of
//! the fault-tolerance story: a 16-bit CRC carried in the version-bumped
//! `LaneStream` v3 header (one per lane payload plus one over the header
//! itself) and optionally sealed into a [`CodedBlock`](crate::codec).
//!
//! The polynomial is CRC-16/CCITT-FALSE (poly `0x1021`, init `0xFFFF`,
//! no reflection, no final xor) — the classic NoC/link-layer choice
//! (HDLC, Bluetooth, SD): cheap in hardware (a 16-bit LFSR), Hamming
//! distance 4 up to ~32 Kbit payloads, so **every** 1-, 2- and 3-bit
//! error inside a lane payload is detected. Residual escape probability
//! for arbitrary multi-bit corruption is 2⁻¹⁶ ≈ 1.5 × 10⁻⁵ (pinned by a
//! seeded trial in the tests and mirrored toolchain-less by
//! `tools/logic_check.py` §[12]).
//!
//! The implementation is table-driven (256-entry, built in a `const fn`
//! so the table is baked into rodata); the bitwise LFSR definition
//! survives in the tests as the independent reference.

/// CRC-16/CCITT-FALSE generator polynomial (x¹⁶+x¹²+x⁵+1).
pub const CRC16_POLY: u16 = 0x1021;

/// CRC-16/CCITT-FALSE initial register value.
pub const CRC16_INIT: u16 = 0xFFFF;

/// Byte-at-a-time lookup table, one entry per input byte value.
const CRC16_TABLE: [u16; 256] = build_table();

const fn build_table() -> [u16; 256] {
    let mut table = [0u16; 256];
    let mut b = 0usize;
    while b < 256 {
        let mut crc = (b as u16) << 8;
        let mut bit = 0;
        while bit < 8 {
            crc = if crc & 0x8000 != 0 {
                (crc << 1) ^ CRC16_POLY
            } else {
                crc << 1
            };
            bit += 1;
        }
        table[b] = crc;
        b += 1;
    }
    table
}

/// Fold `bytes` into a running CRC (streaming form; start from
/// [`CRC16_INIT`]).
#[inline]
pub fn crc16_update(mut crc: u16, bytes: &[u8]) -> u16 {
    for &b in bytes {
        crc = (crc << 8) ^ CRC16_TABLE[((crc >> 8) ^ b as u16) as usize];
    }
    crc
}

/// CRC-16/CCITT-FALSE of `bytes` in one call.
#[inline]
pub fn crc16(bytes: &[u8]) -> u16 {
    crc16_update(CRC16_INIT, bytes)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Bit-at-a-time LFSR — the independent reference the table-driven
    /// implementation is checked against.
    fn crc16_bitwise(bytes: &[u8]) -> u16 {
        let mut crc = CRC16_INIT;
        for &b in bytes {
            crc ^= (b as u16) << 8;
            for _ in 0..8 {
                crc = if crc & 0x8000 != 0 {
                    (crc << 1) ^ CRC16_POLY
                } else {
                    crc << 1
                };
            }
        }
        crc
    }

    #[test]
    fn known_check_value() {
        // The canonical CRC-16/CCITT-FALSE check: crc("123456789") = 0x29B1.
        assert_eq!(crc16(b"123456789"), 0x29B1);
        assert_eq!(crc16(b""), CRC16_INIT);
    }

    #[test]
    fn table_matches_bitwise_reference() {
        let mut rng = crate::prng::Rng::new(0x1521_06);
        for _ in 0..200 {
            let n = rng.below(512) as usize;
            let buf: Vec<u8> = (0..n).map(|_| rng.next_u64() as u8).collect();
            assert_eq!(crc16(&buf), crc16_bitwise(&buf));
        }
    }

    #[test]
    fn streaming_equals_one_shot() {
        let buf: Vec<u8> = (0..257u32).map(|i| (i * 37) as u8).collect();
        for split in [0usize, 1, 7, 128, buf.len()] {
            let (a, b) = buf.split_at(split);
            assert_eq!(crc16_update(crc16_update(CRC16_INIT, a), b), crc16(&buf));
        }
    }

    #[test]
    fn every_single_bit_flip_detected() {
        // Hamming distance ≥ 2 at any length: exhaustive over a 64-byte
        // buffer, every bit position.
        let buf: Vec<u8> = (0..64u32).map(|i| (i * 151 + 3) as u8).collect();
        let clean = crc16(&buf);
        for byte in 0..buf.len() {
            for bit in 0..8 {
                let mut dirty = buf.clone();
                dirty[byte] ^= 1 << bit;
                assert_ne!(crc16(&dirty), clean, "flip at {byte}:{bit} escaped");
            }
        }
    }

    #[test]
    fn multi_bit_escape_rate_is_two_to_minus_sixteen() {
        // Random (≥ 4-bit) corruption escapes a 16-bit CRC with
        // probability ≈ 2⁻¹⁶. Pin the seeded measurement so the residual
        // risk documented in DESIGN.md stays honest: over 60 000 trials
        // the expected escape count is ~0.9 — allow a few, require it
        // stays rare.
        let mut rng = crate::prng::Rng::new(0xE5C4_9A7E);
        let buf: Vec<u8> = (0..96u32).map(|i| (i * 29 + 11) as u8).collect();
        let clean = crc16(&buf);
        let trials = 60_000u32;
        let mut escapes = 0u32;
        for _ in 0..trials {
            let mut dirty = buf.clone();
            for _ in 0..4 {
                let pos = rng.below((dirty.len() * 8) as u64) as usize;
                dirty[pos / 8] ^= 1 << (pos % 8);
            }
            // A flip set that cancels itself leaves the buffer clean —
            // not an escape.
            if dirty != buf && crc16(&dirty) == clean {
                escapes += 1;
            }
        }
        assert!(
            escapes <= 6,
            "multi-bit escape rate far above 2^-16: {escapes}/{trials}"
        );
    }
}
