//! Base–Delta–Immediate baseline (paper Table 2, ref. Pekhimenko et al.
//! [30]), specialized to 8-bit exponent streams.
//!
//! Each fixed-size block is encoded as a tag, an 8-bit base (the block's
//! **midrange** — halfway between the block min and max, which minimizes
//! the needed two's-complement delta width), and per-element deltas of
//! the narrowest width in {0, 1, 2, 3, 4, 5} bits that covers all
//! deltas; blocks that fit no width fall back to raw bytes. The paper
//! quotes "3-bit delta encoding" and a ~2.4× exponent CR; the adaptive
//! widths reproduce that operating point on realistic exponent streams
//! (3-bit is the commonly selected width).
//!
//! Wire layout (MSB-first; the independent Python mirror lives in
//! `tools/logic_check.py` §BDI):
//!
//! ```text
//! compress:       { count:32 | block* }
//! delta block:    { tag:3 = width index | base:8 | delta:width × n }
//! raw block:      { tag:3 = 6           | byte:8 × n }
//! ```
//!
//! The headerless block stream ([`encode_blocks`] / [`decode_blocks`])
//! is also what `flit::pack` embeds per flit when the transfer's
//! [`CodecKind::Bdi`](crate::codec::CodecKind) is selected — the flit
//! header already carries the element count.

use crate::bitstream::{BitReader, BitWriter};
use crate::error::{Error, Result};

/// Elements per BDI block (cache-line-like granule).
pub const BLOCK: usize = 32;
/// Candidate delta widths; tag encodes the choice (plus raw fallback).
/// The base is the block midrange, so width w covers a span of 2^w.
const WIDTHS: [u32; 6] = [0, 1, 2, 3, 4, 5];
const TAG_BITS: u32 = 3;
const TAG_RAW: u64 = WIDTHS.len() as u64;

/// Smallest possible encoded block: tag + base, zero-width deltas. Used
/// to bound hostile count headers before any allocation.
pub const MIN_BLOCK_BITS: usize = (TAG_BITS + 8) as usize;

/// A compressed BDI block stream.
#[derive(Clone, Debug)]
pub struct BdiBlock {
    pub bytes: Vec<u8>,
    pub bits: usize,
    pub count: usize,
}

impl BdiBlock {
    /// Compression ratio vs raw 8-bit symbols.
    pub fn ratio(&self) -> f64 {
        (self.count as f64 * 8.0) / self.bits as f64
    }
}

/// The base minimizing the needed delta width: the block midrange.
fn pick_base(block: &[u8]) -> u8 {
    let min = *block.iter().min().expect("non-empty block");
    let max = *block.iter().max().expect("non-empty block");
    min + (max - min) / 2
}

/// Narrowest width (index into WIDTHS) covering all signed deltas from
/// `base`, or None if even the widest is insufficient.
fn pick_width(block: &[u8], base: u8) -> Option<usize> {
    let mut need: u32 = 0;
    let widest = *WIDTHS.last().expect("non-empty widths");
    for &v in block {
        let d = v as i16 - base as i16; // in [-255, 255]
        let w = signed_width(d);
        need = need.max(w);
        if need > widest {
            return None;
        }
    }
    WIDTHS.iter().position(|&w| w >= need)
}

/// Bits needed to store `d` in two's complement.
fn signed_width(d: i16) -> u32 {
    if d == 0 {
        0
    } else if d > 0 {
        16 - (d as u16).leading_zeros() + 1
    } else {
        16 - ((-(d as i32) - 1) as u16).leading_zeros() + 1
    }
}

/// Exact encoded size in bits of one block (≤ [`BLOCK`] elements) —
/// `tag + base + width·n` or `tag + 8·n` for the raw fallback. This is
/// the pricing function `flit::pack`'s greedy fill uses; it mirrors
/// [`encode_blocks`] exactly (asserted by tests).
pub fn block_bits(block: &[u8]) -> usize {
    debug_assert!(!block.is_empty() && block.len() <= BLOCK);
    let base = pick_base(block);
    match pick_width(block, base) {
        Some(wi) => MIN_BLOCK_BITS + WIDTHS[wi] as usize * block.len(),
        None => TAG_BITS as usize + 8 * block.len(),
    }
}

/// Exact headerless stream size in bits for a whole byte stream.
pub fn stream_bits(data: &[u8]) -> usize {
    data.chunks(BLOCK).map(block_bits).sum()
}

/// Per-block decode-cycle cost under the simple hardware model the sim
/// charges BDI with (ISSUE 3): one cycle each for the tag and base
/// fetches plus one per delta; a raw block skips the base fetch. No
/// codebook pipeline, so (unlike Huffman) there is no startup cost.
pub fn block_decode_cycles(data: &[u8]) -> Vec<u64> {
    data.chunks(BLOCK)
        .map(|b| {
            let base = pick_base(b);
            match pick_width(b, base) {
                Some(_) => 2 + b.len() as u64,
                None => 1 + b.len() as u64,
            }
        })
        .collect()
}

/// Write the headerless block stream for `data` (chunks of [`BLOCK`]).
pub fn encode_blocks(data: &[u8], w: &mut BitWriter) {
    for block in data.chunks(BLOCK) {
        let base = pick_base(block);
        match pick_width(block, base) {
            Some(wi) => {
                let width = WIDTHS[wi];
                w.put(wi as u64, TAG_BITS);
                w.put(base as u64, 8);
                if width > 0 {
                    for &v in block {
                        let d = (v as i16 - base as i16) as u64 & ((1 << width) - 1);
                        w.put(d, width);
                    }
                }
            }
            None => {
                w.put(TAG_RAW, TAG_BITS);
                for &v in block {
                    w.put(v as u64, 8);
                }
            }
        }
    }
}

/// Read exactly `out.len()` symbols of headerless block stream from `r`.
/// Lossless inverse of [`encode_blocks`].
pub fn decode_blocks(r: &mut BitReader, out: &mut [u8]) -> Result<()> {
    let mut done = 0usize;
    while done < out.len() {
        let n = (out.len() - done).min(BLOCK);
        let tag = r.get(TAG_BITS)?;
        if tag == TAG_RAW {
            for slot in &mut out[done..done + n] {
                *slot = r.get(8)? as u8;
            }
        } else {
            let width = *WIDTHS
                .get(tag as usize)
                .ok_or(Error::InvalidCodeword { offset: r.pos() })?;
            let base = r.get(8)? as i16;
            if width == 0 {
                for slot in &mut out[done..done + n] {
                    *slot = base as u8;
                }
            } else {
                for slot in &mut out[done..done + n] {
                    let raw = r.get(width)?;
                    // Sign-extend.
                    let shift = 64 - width;
                    let d = ((raw << shift) as i64) >> shift;
                    // The encoder never writes base+delta outside u8
                    // (the base is the block midrange), so an
                    // out-of-range value is corrupted or forged input —
                    // ISSUE 6: error out instead of silently wrapping.
                    let v = base + d as i16;
                    if !(0..=255).contains(&v) {
                        return Err(Error::Corrupt {
                            block: done / BLOCK,
                            lane: 0,
                        });
                    }
                    *slot = v as u8;
                }
            }
        }
        done += n;
    }
    Ok(())
}

/// Compress a byte stream with adaptive-width BDI.
pub fn compress(data: &[u8]) -> BdiBlock {
    let mut w = BitWriter::new();
    // Cheap capacity bound — 8·n + MIN_BLOCK_BITS per block dominates
    // both block shapes (raw: 3 + 8n, delta: 11 + wn with w ≤ 5 < 8) —
    // rather than an exact `stream_bits` pass that would rerun the
    // base/width analysis encode_blocks is about to do anyway.
    let blocks = data.len().div_ceil(BLOCK) as u64;
    w.reserve_bits(32 + data.len() as u64 * 8 + blocks * MIN_BLOCK_BITS as u64);
    w.put(data.len() as u64, 32);
    encode_blocks(data, &mut w);
    let bits = w.len_bits();
    BdiBlock {
        bytes: w.into_bytes(),
        bits,
        count: data.len(),
    }
}

/// Decompress a BDI stream. Lossless inverse of [`compress`].
pub fn decompress(block: &BdiBlock) -> Result<Vec<u8>> {
    decompress_bits(&block.bytes, block.bits)
}

/// Decompress from raw parts (what [`crate::codec::BdiCodec`] and
/// forged-header tests use).
pub fn decompress_bits(bytes: &[u8], bits: usize) -> Result<Vec<u8>> {
    let mut r = BitReader::with_len(bytes, bits.min(bytes.len() * 8));
    let count = r.get(32)? as usize;
    // Bound the untrusted count by the remaining payload before the
    // output allocation: `count` symbols need at least
    // ceil(count / BLOCK) blocks of ≥ MIN_BLOCK_BITS each — the same
    // hardening as `huffman::decompress_exponents`'s count-header guard;
    // a hostile header cannot demand a multi-gigabyte zero-fill from a
    // tiny block.
    let min_bits = count.div_ceil(BLOCK).saturating_mul(MIN_BLOCK_BITS);
    if min_bits > r.remaining() {
        return Err(Error::InvalidParameter(format!(
            "BDI header claims {count} symbols (≥{min_bits} bits) but only {} payload bits remain",
            r.remaining()
        )));
    }
    let mut out = vec![0u8; count];
    decode_blocks(&mut r, &mut out)?;
    Ok(out)
}

/// Pure coding ratio (header excluded), as Table 2 reports.
pub fn coding_ratio(data: &[u8]) -> f64 {
    if data.is_empty() {
        return 1.0;
    }
    let block = compress(data);
    (data.len() as f64 * 8.0) / (block.bits as f64 - 32.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest::check;

    #[test]
    fn constant_block_uses_zero_width() {
        let data = vec![100u8; BLOCK * 4];
        // 4 blocks × (3 tag + 8 base) = 44 bits.
        let b = compress(&data);
        assert_eq!(b.bits, 32 + 4 * 11);
        assert_eq!(decompress(&b).unwrap(), data);
    }

    #[test]
    fn narrow_deltas_give_paper_band() {
        // Exponents within ±3 of a base → 3-bit deltas → CR ≈ 8/3-ish.
        let data: Vec<u8> = (0..32 * 100).map(|i| 120 + (i % 7) as u8).collect();
        let r = coding_ratio(&data);
        assert!((1.8..2.8).contains(&r), "ratio {r}");
        let b = compress(&data);
        assert_eq!(decompress(&b).unwrap(), data);
    }

    #[test]
    fn wide_blocks_fall_back_to_raw() {
        let data: Vec<u8> = (0..BLOCK as u32 * 4).map(|i| (i * 67) as u8).collect();
        let b = compress(&data);
        assert_eq!(decompress(&b).unwrap(), data);
        let r = coding_ratio(&data);
        assert!(r < 1.05, "ratio {r}");
    }

    #[test]
    fn tail_block_shorter_than_32() {
        let data: Vec<u8> = (0..45).map(|i| 100 + (i % 3) as u8).collect();
        let b = compress(&data);
        assert_eq!(decompress(&b).unwrap(), data);
    }

    #[test]
    fn prop_roundtrip() {
        check("bdi roundtrip", 200, |g| {
            let n = g.usize(1..3000);
            let data = if g.bool(0.6) {
                { let a = g.usize(1..16); g.skewed_bytes(n, a) }
            } else {
                g.vec(n, |g| g.u8())
            };
            let b = compress(&data);
            assert_eq!(decompress(&b).unwrap(), data);
        });
    }

    #[test]
    fn prop_esc_heavy_and_constant_streams_roundtrip() {
        // ISSUE 3 satellite: mixed-regime streams — long constant runs
        // (width-0 blocks) spliced with full-range noise (raw-fallback
        // blocks) — exercise every tag on one stream.
        check("bdi mixed-regime roundtrip", 120, |g| {
            let mut data = Vec::new();
            for _ in 0..g.usize(1..8) {
                match g.usize(0..3) {
                    0 => data.extend(vec![g.u8(); g.usize(1..120)]),
                    1 => {
                        let n = g.usize(1..120);
                        data.extend(g.vec(n, |g| g.u8()));
                    }
                    _ => {
                        let base = g.u8();
                        let n = g.usize(1..120);
                        data.extend(
                            g.vec(n, |g| base.wrapping_add(g.usize(0..7) as u8)),
                        );
                    }
                }
            }
            let b = compress(&data);
            assert_eq!(decompress(&b).unwrap(), data);
        });
    }

    #[test]
    fn prop_truncated_input_rejected() {
        // Any strict bit truncation must error, never mis-decode to a
        // full-length output.
        check("bdi truncation rejected", 80, |g| {
            let n = g.usize(1..1500);
            let data = { let a = g.usize(1..24); g.skewed_bytes(n, a) };
            let b = compress(&data);
            let cut = g.usize(1..b.bits);
            let short_bits = b.bits - cut;
            let mut bytes = b.bytes.clone();
            bytes.truncate(short_bits.div_ceil(8));
            match decompress_bits(&bytes, short_bits) {
                Err(_) => {}
                Ok(out) => assert_ne!(out, data, "truncated stream silently decoded"),
            }
        });
    }

    #[test]
    fn hostile_count_rejected_before_allocation() {
        // Forge the 32-bit count header to u32::MAX on a tiny valid
        // stream: the guard must reject on the minimum-block-bits bound
        // instead of zero-filling a 4 GiB output first.
        let data = vec![7u8; 64];
        let b = compress(&data);
        let mut forged = b.bytes.clone();
        for byte in forged.iter_mut().take(4) {
            *byte = 0xff;
        }
        let err = decompress_bits(&forged, b.bits).unwrap_err();
        assert!(matches!(err, Error::InvalidParameter(_)), "{err:?}");
        // And a count only slightly too large for the payload also dies.
        let mut bumped = b.bytes.clone();
        // count occupies the first 4 bytes big-endian; 64 → claim 320,
        // still far beyond the 2 width-0 blocks the payload holds.
        bumped[2] = 0x01;
        let err2 = decompress_bits(&bumped, b.bits).unwrap_err();
        assert!(matches!(err2, Error::InvalidParameter(_)), "{err2:?}");
    }

    #[test]
    fn prop_block_bits_matches_encoder() {
        // The flit greedy fill prices BDI sections with block_bits /
        // stream_bits; they must agree with the writer bit-for-bit.
        check("bdi pricing == encoder", 100, |g| {
            let n = g.usize(1..2000);
            let data = if g.bool(0.5) {
                { let a = g.usize(1..40); g.skewed_bytes(n, a) }
            } else {
                g.vec(n, |g| g.u8())
            };
            let mut w = BitWriter::new();
            encode_blocks(&data, &mut w);
            assert_eq!(w.len_bits(), stream_bits(&data));
            let b = compress(&data);
            assert_eq!(b.bits, 32 + stream_bits(&data));
        });
    }

    #[test]
    fn decode_cycle_model_bounds() {
        let data: Vec<u8> = (0..BLOCK * 3 + 5).map(|i| (i * 31) as u8).collect();
        let costs = block_decode_cycles(&data);
        assert_eq!(costs.len(), 4);
        for (i, &c) in costs.iter().enumerate() {
            let n = if i < 3 { BLOCK as u64 } else { 5 };
            assert!((n + 1..=n + 2).contains(&c), "block {i} cost {c}");
        }
    }

    #[test]
    fn out_of_range_delta_is_corrupt_not_wraparound() {
        // ISSUE 6 audit: a forged delta block whose base+delta leaves
        // the u8 range used to wrap around silently; it must now be a
        // typed Corrupt error identifying the block.
        let mut w = BitWriter::new();
        w.put(5, TAG_BITS); // width index 5 → 5-bit deltas
        w.put(255, 8); // base at the top of the range
        w.put(0b01111, 5); // +15 → 270: unrepresentable
        let bits = w.len_bits();
        let bytes = w.into_bytes();
        let mut r = BitReader::with_len(&bytes, bits);
        let mut out = [0u8; 1];
        assert_eq!(
            decode_blocks(&mut r, &mut out).unwrap_err(),
            Error::Corrupt { block: 0, lane: 0 }
        );
        // Negative overflow too: base 0, delta −16.
        let mut w = BitWriter::new();
        w.put(5, TAG_BITS);
        w.put(0, 8);
        w.put(0b10000, 5); // −16 → −16
        let bits = w.len_bits();
        let bytes = w.into_bytes();
        let mut r = BitReader::with_len(&bytes, bits);
        assert_eq!(
            decode_blocks(&mut r, &mut out).unwrap_err(),
            Error::Corrupt { block: 0, lane: 0 }
        );
    }

    #[test]
    fn signed_width_cases() {
        assert_eq!(signed_width(0), 0);
        assert_eq!(signed_width(1), 2);
        assert_eq!(signed_width(-1), 1);
        assert_eq!(signed_width(3), 3);
        assert_eq!(signed_width(-4), 3);
        assert_eq!(signed_width(7), 4);
        assert_eq!(signed_width(-8), 4);
    }
}
