//! Base–Delta–Immediate baseline (paper Table 2, ref. Pekhimenko et al.
//! [30]), specialized to 8-bit exponent streams.
//!
//! Each fixed-size block is encoded as a tag, an 8-bit base (the block's
//! first value), and per-element deltas of the narrowest width in
//! {0, 1, 2, 3, 4} bits that covers all deltas; blocks that fit no width
//! fall back to raw bytes. The paper quotes "3-bit delta encoding" and a
//! ~2.4× exponent CR; the adaptive widths reproduce that operating point
//! on realistic exponent streams (3-bit is the commonly selected width).

use crate::bitstream::{BitReader, BitWriter};
use crate::error::{Error, Result};

/// Elements per BDI block (cache-line-like granule).
pub const BLOCK: usize = 32;
/// Candidate delta widths; tag encodes the choice (plus raw fallback).
/// The base is the block midrange, so width w covers a span of 2^w.
const WIDTHS: [u32; 6] = [0, 1, 2, 3, 4, 5];
const TAG_BITS: u32 = 3;
const TAG_RAW: u64 = WIDTHS.len() as u64;

/// A compressed BDI block stream.
#[derive(Clone, Debug)]
pub struct BdiBlock {
    pub bytes: Vec<u8>,
    pub bits: usize,
    pub count: usize,
}

impl BdiBlock {
    /// Compression ratio vs raw 8-bit symbols.
    pub fn ratio(&self) -> f64 {
        (self.count as f64 * 8.0) / self.bits as f64
    }
}

/// The base minimizing the needed delta width: the block midrange.
fn pick_base(block: &[u8]) -> u8 {
    let min = *block.iter().min().expect("non-empty block");
    let max = *block.iter().max().expect("non-empty block");
    min + (max - min) / 2
}

/// Narrowest width (index into WIDTHS) covering all signed deltas from
/// `base`, or None if even the widest is insufficient.
fn pick_width(block: &[u8], base: u8) -> Option<usize> {
    let mut need: u32 = 0;
    let widest = *WIDTHS.last().expect("non-empty widths");
    for &v in block {
        let d = v as i16 - base as i16; // in [-255, 255]
        let w = signed_width(d);
        need = need.max(w);
        if need > widest {
            return None;
        }
    }
    WIDTHS.iter().position(|&w| w >= need)
}

/// Bits needed to store `d` in two's complement.
fn signed_width(d: i16) -> u32 {
    if d == 0 {
        0
    } else if d > 0 {
        16 - (d as u16).leading_zeros() + 1
    } else {
        16 - ((-(d as i32) - 1) as u16).leading_zeros() + 1
    }
}

/// Compress a byte stream with adaptive-width BDI.
pub fn compress(data: &[u8]) -> BdiBlock {
    let mut w = BitWriter::new();
    w.put(data.len() as u64, 32);
    for block in data.chunks(BLOCK) {
        let base = pick_base(block);
        match pick_width(block, base) {
            Some(wi) => {
                let width = WIDTHS[wi];
                w.put(wi as u64, TAG_BITS);
                w.put(base as u64, 8);
                if width > 0 {
                    for &v in block {
                        let d = (v as i16 - base as i16) as u64 & ((1 << width) - 1);
                        w.put(d, width);
                    }
                }
            }
            None => {
                w.put(TAG_RAW, TAG_BITS);
                for &v in block {
                    w.put(v as u64, 8);
                }
            }
        }
    }
    let bits = w.len_bits();
    BdiBlock {
        bytes: w.into_bytes(),
        bits,
        count: data.len(),
    }
}

/// Decompress a BDI stream. Lossless inverse of [`compress`].
pub fn decompress(block: &BdiBlock) -> Result<Vec<u8>> {
    let mut r = BitReader::with_len(&block.bytes, block.bits);
    let count = r.get(32)? as usize;
    let mut out = Vec::with_capacity(count);
    while out.len() < count {
        let n = (count - out.len()).min(BLOCK);
        let tag = r.get(TAG_BITS)?;
        if tag == TAG_RAW {
            for _ in 0..n {
                out.push(r.get(8)? as u8);
            }
        } else {
            let width = *WIDTHS
                .get(tag as usize)
                .ok_or(Error::InvalidCodeword { offset: r.pos() })?;
            let base = r.get(8)? as i16;
            if width == 0 {
                for _ in 0..n {
                    out.push(base as u8);
                }
            } else {
                for _ in 0..n {
                    let raw = r.get(width)?;
                    // Sign-extend.
                    let shift = 64 - width;
                    let d = ((raw << shift) as i64) >> shift;
                    out.push((base + d as i16) as u8);
                }
            }
        }
    }
    Ok(out)
}

/// Pure coding ratio (header excluded), as Table 2 reports.
pub fn coding_ratio(data: &[u8]) -> f64 {
    if data.is_empty() {
        return 1.0;
    }
    let block = compress(data);
    (data.len() as f64 * 8.0) / (block.bits as f64 - 32.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest::check;

    #[test]
    fn constant_block_uses_zero_width() {
        let data = vec![100u8; BLOCK * 4];
        // 4 blocks × (3 tag + 8 base) = 44 bits.
        let b = compress(&data);
        assert_eq!(b.bits, 32 + 4 * 11);
        assert_eq!(decompress(&b).unwrap(), data);
    }

    #[test]
    fn narrow_deltas_give_paper_band() {
        // Exponents within ±3 of a base → 3-bit deltas → CR ≈ 8/3-ish.
        let data: Vec<u8> = (0..32 * 100).map(|i| 120 + (i % 7) as u8).collect();
        let r = coding_ratio(&data);
        assert!((1.8..2.8).contains(&r), "ratio {r}");
        let b = compress(&data);
        assert_eq!(decompress(&b).unwrap(), data);
    }

    #[test]
    fn wide_blocks_fall_back_to_raw() {
        let data: Vec<u8> = (0..BLOCK as u32 * 4).map(|i| (i * 67) as u8).collect();
        let b = compress(&data);
        assert_eq!(decompress(&b).unwrap(), data);
        let r = coding_ratio(&data);
        assert!(r < 1.05, "ratio {r}");
    }

    #[test]
    fn tail_block_shorter_than_32() {
        let data: Vec<u8> = (0..45).map(|i| 100 + (i % 3) as u8).collect();
        let b = compress(&data);
        assert_eq!(decompress(&b).unwrap(), data);
    }

    #[test]
    fn prop_roundtrip() {
        check("bdi roundtrip", 200, |g| {
            let n = g.usize(1..3000);
            let data = if g.bool(0.6) {
                { let a = g.usize(1..16); g.skewed_bytes(n, a) }
            } else {
                g.vec(n, |g| g.u8())
            };
            let b = compress(&data);
            assert_eq!(decompress(&b).unwrap(), data);
        });
    }

    #[test]
    fn signed_width_cases() {
        assert_eq!(signed_width(0), 0);
        assert_eq!(signed_width(1), 2);
        assert_eq!(signed_width(-1), 1);
        assert_eq!(signed_width(3), 3);
        assert_eq!(signed_width(-4), 3);
        assert_eq!(signed_width(7), 4);
        assert_eq!(signed_width(-8), 4);
    }
}
