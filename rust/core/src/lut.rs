//! §Perf — the multi-symbol decode LUT (ISSUE 4 tentpole).
//!
//! The paper's decoders "sustain the maximum link bandwidth via
//! multi-lane LUT decoders" (§4.4) precisely because exponent streams
//! carry < 3 bits of entropy: the [`LUT_BITS`]-bit window the fast table
//! already peeks typically holds **3–4 complete codewords**, yet the
//! single-symbol table decodes one and loops. [`MultiDecodeTable`] is the
//! zstd/FSE trick applied to the canonical exponent code: a direct table
//! indexed by the next [`LUT_BITS`] bits where each entry packs up to
//! [`LUT_MAX_SYMS`] already-decoded exponents plus the total bits they
//! consume, so one probe emits a whole run.
//!
//! ## Entry layout (one `u64` per probe)
//!
//! ```text
//! bits  0..32   up to 4 decoded exponents, first-decoded in byte 0
//!               (out[i..i+n] is literally to_le_bytes()[..n])
//! bits 32..36   symbol count n (0 = sentinel: fall back to the scalar
//!               kernel — ESC-leading, long-code, or partial patterns)
//! bits 40..48   total bits consumed (≤ LUT_BITS)
//! ```
//!
//! ## Fill algorithm
//!
//! The decoder's single-symbol fast table (same width: `huffman`
//! compile-asserts `FAST_BITS == LUT_BITS`) already classifies every
//! probe prefix — a dedicated `(sym, len)` hit, or a miss covering both
//! ESC and codes longer than the window, the two cases that stop a pack
//! identically. Each of the `2^LUT_BITS` entries then greedily
//! re-probes its own suffix (`(p << used) & mask`: consumed bits shift
//! out, zeros shift in) and appends codewords while they fit **entirely
//! inside the known bits** — a codeword of length ≤ the remaining probe
//! bits decodes identically under every window extension (prefix
//! property), so packed symbols are exact, never speculative.
//!
//! ## Fallback contract
//!
//! The table is an accelerator, not a decoder: consumers use an entry
//! only when `count ≥ 1`, the caller still wants ≥ `count` symbols, and
//! `consumed ≤ remaining` readable bits. Everything else — ESC resolution
//! (needs the raw byte), codes longer than the window, stream tails, and
//! exhaustion errors — falls back to the scalar
//! [`decode_from_window`] kernel, which is why every LUT path is
//! bit-identical to the canonical decoder *including error details*
//! (property-pinned here and in `huffman`/`batch`).
//!
//! [`decode_from_window`]: crate::huffman::CanonicalDecoder
//!
//! Robustness (ISSUE 6 audit): the LUT is a pure accelerator — entries
//! only fire on fully-decoded, in-window codeword runs; every partial,
//! ESC-leading, or malformed pattern is the `n = 0` sentinel, which
//! falls back to the scalar kernel and its typed [`Error`] handling. A
//! corrupted stream therefore fails exactly where the scalar decoder
//! fails; the LUT can neither panic nor fabricate symbols.

use crate::huffman::{CanonicalDecoder, CodeBook};

/// Probe width in bits. 2^11 entries × 8 B = 16 KiB — L1-resident, and
/// wide enough that a < 3-bit-entropy stream packs 3–4 codewords per
/// probe. Tunable at compile time; K ∈ 11..=12 is the sweet spot (13+
/// doubles the table past half of L1 for < 2% extra fill).
pub const LUT_BITS: u32 = 11;

/// Maximum symbols packed per entry (4 × 8-bit exponents fill the low
/// 32 bits of the entry word; more would widen the entry and the copy).
pub const LUT_MAX_SYMS: usize = 4;

/// Block-decode callers only build the table when a stream carries at
/// least this many symbols: the fill walks `2^LUT_BITS` probes, which a
/// short block never amortizes.
pub const LUT_DECODE_MIN_SYMBOLS: usize = 4096;

/// The one build-or-not policy every decode surface consults
/// ([`CodeBook::decoder_for`], the lockstep lane split): does a block of
/// `symbols` amortize **one** table fill? Callers paying several fills
/// (per-lane books) pass each table's share, not the total.
///
/// [`CodeBook::decoder_for`]: crate::huffman::CodeBook::decoder_for
#[inline]
pub fn amortizes_fill(symbols: usize) -> bool {
    symbols >= LUT_DECODE_MIN_SYMBOLS
}

/// Table size in entries.
const ENTRIES: usize = 1 << LUT_BITS;

/// A multi-symbol direct decode table for one [`CodeBook`].
#[derive(Clone, Debug)]
pub struct MultiDecodeTable {
    /// One packed entry per probe (layout in the module docs).
    entries: Vec<u64>,
    /// Mean symbols per probe over all `2^LUT_BITS` patterns, sentinel
    /// probes counted as 1 (they still emit one symbol via the fallback
    /// kernel). The hw model derives its symbols-per-cycle from this.
    avg_fill: f64,
}

impl MultiDecodeTable {
    /// Build the table for `book`. Convenience over [`from_decoder`]
    /// when no decoder exists yet ([`CodeBook::lut_decoder`] reuses the
    /// one it is already building instead).
    ///
    /// [`from_decoder`]: MultiDecodeTable::from_decoder
    pub fn new(book: &CodeBook) -> Self {
        Self::from_decoder(&book.decoder())
    }

    /// Build the table from a decoder's single-symbol fast table, which
    /// is exactly the scratch classifier the pack loop needs: a hit is a
    /// dedicated `(sym, len ≤ LUT_BITS)` codeword, and a miss covers
    /// both ESC (excluded from the fast fill: the raw byte may extend
    /// past the probe) and codes longer than the window — the two cases
    /// that stop a pack identically. Reusing it keeps the subtle
    /// canonical-walk fill in one place (`huffman` compile-asserts
    /// `FAST_BITS == LUT_BITS`) and makes `lut_decoder` a single
    /// canonical fill plus this `O(2^LUT_BITS · LUT_MAX_SYMS)` pack pass
    /// (the `lut build` bench row keeps the cost visible).
    pub(crate) fn from_decoder(dec: &CanonicalDecoder) -> Self {
        let fast = dec.fast_table();
        debug_assert_eq!(fast.len(), ENTRIES);
        let mut entries = vec![0u64; ENTRIES];
        let mut total_syms = 0u64;
        for (p, entry) in entries.iter_mut().enumerate() {
            let mut e = 0u64;
            let mut used = 0u32;
            let mut count = 0u32;
            while (count as usize) < LUT_MAX_SYMS {
                let rem = LUT_BITS - used;
                if rem == 0 {
                    break;
                }
                // Consumed bits shift out of the probe, zeros shift in;
                // a hit is trusted only when it fits the known bits.
                let s = fast[(p << used) & (ENTRIES - 1)];
                if s == crate::huffman::FAST_MISS {
                    break;
                }
                let len = s & 0xff;
                if len > rem {
                    break;
                }
                e |= ((s >> 8) as u64) << (8 * count);
                used += len;
                count += 1;
            }
            if count > 0 {
                e |= (count as u64) << 32 | (used as u64) << 40;
            }
            *entry = e;
            total_syms += count.max(1) as u64;
        }
        MultiDecodeTable {
            entries,
            avg_fill: total_syms as f64 / ENTRIES as f64,
        }
    }

    /// The entry for a left-aligned 64-bit window (top [`LUT_BITS`] bits
    /// are the probe).
    #[inline]
    pub fn entry(&self, window: u64) -> u64 {
        self.entries[(window >> (64 - LUT_BITS)) as usize]
    }

    /// The entry for a raw [`LUT_BITS`]-bit probe (hardware-model path,
    /// fed from `BitReader::peek_zeroext(LUT_BITS)`).
    #[inline]
    pub fn entry_at(&self, probe: usize) -> u64 {
        self.entries[probe]
    }

    /// The raw probe-indexed entry table (ISSUE 8): the grouped lockstep
    /// decoder's gather path (`swar::gather`, optionally a real AVX2
    /// `vpgatherqq`) loads several lanes' entries from it in one step.
    /// `entries()[p] == entry_at(p)` for every probe.
    #[inline]
    pub fn entries(&self) -> &[u64] {
        &self.entries
    }

    /// Symbols packed in `entry` (0 = sentinel, use the fallback kernel).
    #[inline]
    pub fn count(entry: u64) -> u32 {
        ((entry >> 32) & 0xf) as u32
    }

    /// Total bits the packed symbols consume.
    #[inline]
    pub fn consumed(entry: u64) -> u32 {
        ((entry >> 40) & 0xff) as u32
    }

    /// The `j`-th packed symbol (first decoded at `j = 0`).
    #[inline]
    pub fn symbol(entry: u64, j: u32) -> u8 {
        (entry >> (8 * j)) as u8
    }

    /// Mean symbols per probe over all patterns (sentinels count as 1);
    /// ∈ `1.0 ..= LUT_MAX_SYMS`. The hw decoder model's nominal
    /// symbols-per-cycle.
    pub fn avg_fill(&self) -> f64 {
        self.avg_fill
    }

    /// Number of probes a fill walks (hardware fill-latency input).
    pub fn fill_probes() -> u64 {
        ENTRIES as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitstream::{BitReader, BitWriter};
    use crate::huffman::{compress_exponents, decompress_exponents, CodeBook};
    use crate::proptest::check;
    use crate::stats::Histogram;

    fn book_of(data: &[u8]) -> CodeBook {
        CodeBook::lexi_default(&Histogram::from_bytes(data)).unwrap()
    }

    /// Independent per-probe reference: repeatedly find the unique
    /// codeword (prefix-free ⇒ at most one, ESC included) that fits
    /// entirely in the remaining probe bits. No scratch table, no
    /// shift-reindexing — a fill bug and a reference bug can't cancel.
    fn ref_entry(book: &CodeBook, probe: u32) -> (Vec<u8>, u32) {
        let mut syms = Vec::new();
        let mut used = 0u32;
        'outer: while syms.len() < LUT_MAX_SYMS {
            let rem = LUT_BITS - used;
            if rem == 0 {
                break;
            }
            let esc = book.escape();
            if esc.len <= rem && (probe >> (rem - esc.len)) & ((1 << esc.len) - 1) == esc.bits
            {
                break; // ESC stays on the slow path
            }
            for s in 0..=255u8 {
                if let Some(c) = book.code(s) {
                    if c.len <= rem
                        && (probe >> (rem - c.len)) & ((1u32 << c.len) - 1) == c.bits
                    {
                        syms.push(s);
                        used += c.len;
                        continue 'outer;
                    }
                }
            }
            break; // no full codeword fits the known bits
        }
        (syms, used)
    }

    #[test]
    fn prop_entries_match_brute_force_enumeration() {
        check("LUT entries == brute-force probe replay", 12, |g| {
            let n = g.usize(16..3000);
            let data = if g.bool(0.6) {
                let a = g.usize(1..50);
                g.skewed_bytes(n, a)
            } else {
                g.vec(n, |g| g.u8())
            };
            let book = book_of(&data);
            let table = MultiDecodeTable::new(&book);
            for p in 0..(1u32 << LUT_BITS) {
                let e = table.entry_at(p as usize);
                let (want_syms, want_used) = ref_entry(&book, p);
                assert_eq!(
                    MultiDecodeTable::count(e) as usize,
                    want_syms.len(),
                    "probe {p:#013b}: count"
                );
                assert_eq!(
                    MultiDecodeTable::consumed(e),
                    want_used,
                    "probe {p:#013b}: consumed"
                );
                for (j, &s) in want_syms.iter().enumerate() {
                    assert_eq!(
                        MultiDecodeTable::symbol(e, j as u32),
                        s,
                        "probe {p:#013b}: symbol {j}"
                    );
                }
            }
        });
    }

    #[test]
    fn prop_lut_block_decode_is_bit_identical_to_scalar() {
        check("lut decode == scalar decode", 80, |g| {
            let n = g.usize(1..4000);
            // Skewed (LUT-heavy), ESC-heavy uniform, or full-range noise.
            let data = match g.usize(0..3) {
                0 => {
                    let a = g.usize(1..32);
                    g.skewed_bytes(n, a)
                }
                1 => {
                    let a = g.usize(33..140);
                    g.skewed_bytes(n, a)
                }
                _ => g.vec(n, |g| g.u8()),
            };
            let book = book_of(&data);
            let mut w = BitWriter::new();
            for &e in &data {
                book.encode_symbol(e, &mut w);
            }
            let bits = w.len_bits();
            let bytes = w.into_bytes();

            let scalar = book.decoder();
            let lut = book.lut_decoder();
            assert!(lut.multi_table().is_some());

            let mut r1 = BitReader::with_len(&bytes, bits);
            let mut out1 = vec![0u8; n];
            scalar.decode_block_into(&mut r1, &mut out1).unwrap();
            let mut r2 = BitReader::with_len(&bytes, bits);
            let mut out2 = vec![0u8; n];
            lut.decode_block_into(&mut r2, &mut out2).unwrap();

            assert_eq!(out1, data);
            assert_eq!(out2, out1, "lut path diverged from scalar");
            assert_eq!(r1.pos(), r2.pos(), "consumed bit counts diverged");
        });
    }

    #[test]
    fn prop_truncated_streams_error_identically() {
        check("lut decode truncation == scalar errors", 60, |g| {
            let n = g.usize(2..1200);
            let a = g.usize(1..80);
            let data = g.skewed_bytes(n, a);
            let book = book_of(&data);
            let mut w = BitWriter::new();
            for &e in &data {
                book.encode_symbol(e, &mut w);
            }
            let bits = w.len_bits();
            let bytes = w.into_bytes();
            let cut = g.usize(1..bits);
            let short_bits = bits - cut;
            let short = &bytes[..short_bits.div_ceil(8)];

            let run = |dec: &crate::huffman::CanonicalDecoder| {
                let mut r = BitReader::with_len(short, short_bits);
                let mut out = vec![0u8; n];
                dec.decode_block_into(&mut r, &mut out).map(|()| out)
            };
            let scalar = run(&book.decoder());
            let lut = run(&book.lut_decoder());
            // Both must fail — and with the same precise error: the LUT
            // only fires when consumed ≤ remaining, so every tail walks
            // the identical scalar kernel.
            assert!(scalar.is_err(), "scalar accepted a truncated stream");
            assert_eq!(
                scalar.as_ref().err(),
                lut.as_ref().err(),
                "exhaustion details diverged"
            );
        });
    }

    #[test]
    fn degenerate_books_pack_one_symbol_per_entry() {
        // A near-uniform 180-symbol alphabet under a 64-entry book gives
        // every dedicated code ≥ 6 bits: two never fit an 11-bit probe,
        // so the table degenerates to ≤ 1 symbol per entry and decoding
        // leans wholly on the fallback — still bit-exact.
        let data: Vec<u8> = (0..7200u32).map(|i| (i % 180) as u8).collect();
        let hist = Histogram::from_bytes(&data);
        let book = CodeBook::from_histogram(&hist, 64, 24).unwrap();
        let min_len = book
            .canonical_pairs()
            .iter()
            .map(|&(_, l)| l)
            .min()
            .unwrap();
        assert!(min_len > LUT_BITS / 2, "alphabet not degenerate enough");
        let table = MultiDecodeTable::new(&book);
        for p in 0..(1usize << LUT_BITS) {
            let e = table.entry_at(p);
            assert!(
                MultiDecodeTable::count(e) <= LUT_BITS / min_len,
                "probe {p}: over-packed entry"
            );
        }
        assert!(table.avg_fill() <= (LUT_BITS / min_len) as f64);
        // And the public decode path still roundtrips through it.
        let block = crate::huffman::compress_with_book(&data, &book).unwrap();
        assert_eq!(decompress_exponents(&block).unwrap(), data);
    }

    #[test]
    fn skewed_streams_fill_multiple_symbols_per_probe() {
        // Paper-entropy stream (few dominant exponents → short codes):
        // the uniform-probe average fill must exceed 2 symbols/probe.
        let data: Vec<u8> = (0..4000u32).map(|i| 124 + (i % 100 / 45) as u8).collect();
        let book = book_of(&data);
        let table = MultiDecodeTable::new(&book);
        assert!(
            (1.0..=LUT_MAX_SYMS as f64).contains(&table.avg_fill()),
            "avg fill {} out of range",
            table.avg_fill()
        );
        assert!(
            table.avg_fill() > 2.0,
            "avg fill {} too low for a skewed book",
            table.avg_fill()
        );
    }

    #[test]
    fn decompress_path_uses_lut_above_threshold() {
        // Public roundtrip sanity on a stream big enough for the LUT
        // threshold, plus one below it (scalar path) — identical output
        // shape either way.
        for n in [64usize, LUT_DECODE_MIN_SYMBOLS + 1] {
            let data: Vec<u8> = (0..n).map(|i| 120 + (i % 5) as u8).collect();
            let block = compress_exponents(&data).unwrap();
            assert_eq!(decompress_exponents(&block).unwrap(), data, "n {n}");
        }
    }
}
