//! Run-length encoding baseline (paper Table 2, ref. Golomb [12]).
//!
//! Encodes the exponent stream as `(value: 8, run_length: 8)` pairs with
//! runs capped at 255. The paper reports CR ≈ 0.64× — i.e. *expansion* —
//! because identical-exponent runs are short in LLM tensors; we reproduce
//! exactly that behaviour.

use crate::bitstream::{BitReader, BitWriter};
use crate::error::Result;

/// A compressed RLE block.
#[derive(Clone, Debug)]
pub struct RleBlock {
    pub bytes: Vec<u8>,
    pub bits: usize,
    pub count: usize,
}

impl RleBlock {
    /// Compression ratio vs raw 8-bit symbols.
    pub fn ratio(&self) -> f64 {
        (self.count as f64 * 8.0) / self.bits as f64
    }
}

/// Compress a byte stream with byte-aligned RLE.
pub fn compress(data: &[u8]) -> RleBlock {
    let mut w = BitWriter::new();
    w.put(data.len() as u64, 32);
    let mut i = 0;
    while i < data.len() {
        let v = data[i];
        let mut run = 1usize;
        while i + run < data.len() && data[i + run] == v && run < 255 {
            run += 1;
        }
        w.put(v as u64, 8);
        w.put(run as u64, 8);
        i += run;
    }
    let bits = w.len_bits();
    RleBlock {
        bytes: w.into_bytes(),
        bits,
        count: data.len(),
    }
}

/// Decompress an RLE block. Lossless inverse of [`compress`].
pub fn decompress(block: &RleBlock) -> Result<Vec<u8>> {
    let mut r = BitReader::with_len(&block.bytes, block.bits);
    let count = r.get(32)? as usize;
    let mut out = Vec::with_capacity(count);
    while out.len() < count {
        let v = r.get(8)? as u8;
        let run = r.get(8)? as usize;
        for _ in 0..run {
            out.push(v);
        }
    }
    Ok(out)
}

/// Compression ratio ignoring the 32-bit count header (pure coding ratio,
/// what Table 2 reports).
pub fn coding_ratio(data: &[u8]) -> f64 {
    if data.is_empty() {
        return 1.0;
    }
    let block = compress(data);
    (data.len() as f64 * 8.0) / (block.bits as f64 - 32.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest::check;

    #[test]
    fn long_runs_compress() {
        let data = vec![7u8; 1000];
        let r = coding_ratio(&data);
        assert!(r > 100.0, "ratio {r}");
    }

    #[test]
    fn alternating_expands() {
        // No runs → 16 bits per symbol → 0.5× (the paper's 0.64× regime).
        let data: Vec<u8> = (0..1000).map(|i| (i % 2) as u8).collect();
        let r = coding_ratio(&data);
        assert!((0.45..0.55).contains(&r), "ratio {r}");
    }

    #[test]
    fn run_cap_at_255() {
        let data = vec![3u8; 600];
        let block = compress(&data);
        assert_eq!(decompress(&block).unwrap(), data);
        // 600 = 255 + 255 + 90 → 3 pairs.
        assert_eq!(block.bits, 32 + 3 * 16);
    }

    #[test]
    fn prop_roundtrip() {
        check("rle roundtrip", 200, |g| {
            let n = g.usize(0..2000);
            let data = if g.bool(0.5) {
                { let a = g.usize(1..6); g.skewed_bytes(n.max(1), a) }
            } else {
                g.vec(n, |g| g.u8())
            };
            let block = compress(&data);
            assert_eq!(decompress(&block).unwrap(), data);
        });
    }
}
