//! Pluggable exponent-codec layer (ISSUE 3 tentpole).
//!
//! The paper's Table 2 treats the codec as a design axis — LEXI's
//! canonical Huffman against BDI-style delta coding and raw passthrough —
//! and related systems (Huff-LLM, DFloat11; see PAPERS.md) pick different
//! points on it. This module makes that axis a first-class abstraction:
//!
//! * [`ExpCodec`] — the trait every exponent codec implements: encode an
//!   exponent byte stream into a self-describing [`CodedBlock`], decode it
//!   back losslessly, and report the Table 2 coding ratio.
//! * [`CodecKind`] — the registry and **wire tag**. Each kind maps to a
//!   2-bit on-wire identifier (carried by `flit::pack` so `unpack` can
//!   dispatch without out-of-band context) and to a `'static` codec
//!   instance via [`CodecKind::codec`].
//! * [`HuffmanCodec`] / [`BdiCodec`] / [`RawCodec`] — the three built-in
//!   backends. Huffman routes through the exact same
//!   [`huffman::compress_exponents`] batch engine as before, so bytes
//!   produced via the trait are **bit-identical** to the direct path
//!   (pinned by [`tests::huffman_via_trait_is_byte_identical`] and by
//!   `lexi-sim`'s `batch_rewire_preserves_compressed_sizes`).
//!
//! Everything downstream (`sim::compression::CrTable`, `sim::engine`'s
//! per-kind `CodecPolicy` in `lexi-models`, `flit`, the CLI `dse --what
//! codec` sweep) dispatches through this trait instead of naming
//! `huffman::*` directly.

use crate::bdi;
use crate::error::{Error, Result};
use crate::huffman;
use crate::integrity::crc16;

/// Width of the on-wire codec tag (2 bits: 3 codecs + 1 reserved).
pub const CODEC_TAG_BITS: u32 = 2;

/// Registered exponent codecs. The discriminant order is frozen: it is
/// the wire tag (`flit` header) and must never be reshuffled.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum CodecKind {
    /// Canonical Huffman + all-ones escape — the LEXI algorithm, backed
    /// by the §Perf batch/lane engine.
    Huffman,
    /// Base–delta–immediate over 32-element blocks (Table 2 baseline).
    Bdi,
    /// Raw 8-bit passthrough (the "Base" column; also the honest fallback
    /// for incompressible streams).
    Raw,
}

impl CodecKind {
    /// All registered codecs, Table 2 column order.
    pub const ALL: [CodecKind; 3] = [CodecKind::Huffman, CodecKind::Bdi, CodecKind::Raw];

    /// The 2-bit wire tag ([`CODEC_TAG_BITS`]).
    #[inline]
    pub fn wire_tag(self) -> u8 {
        match self {
            CodecKind::Huffman => 0,
            CodecKind::Bdi => 1,
            CodecKind::Raw => 2,
        }
    }

    /// Inverse of [`wire_tag`]; tag 3 is reserved and rejected.
    ///
    /// [`wire_tag`]: CodecKind::wire_tag
    pub fn from_wire_tag(tag: u8) -> Result<Self> {
        match tag {
            0 => Ok(CodecKind::Huffman),
            1 => Ok(CodecKind::Bdi),
            2 => Ok(CodecKind::Raw),
            other => Err(Error::InvalidParameter(format!(
                "unknown codec wire tag {other}"
            ))),
        }
    }

    /// Short stable name (CLI flags, report rows).
    pub fn name(self) -> &'static str {
        match self {
            CodecKind::Huffman => "huffman",
            CodecKind::Bdi => "bdi",
            CodecKind::Raw => "raw",
        }
    }

    /// Parse a [`name`] back into a kind.
    ///
    /// [`name`]: CodecKind::name
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "huffman" | "lexi" => Ok(CodecKind::Huffman),
            "bdi" => Ok(CodecKind::Bdi),
            "raw" | "none" => Ok(CodecKind::Raw),
            other => Err(Error::InvalidParameter(format!(
                "unknown codec '{other}' (want huffman|bdi|raw)"
            ))),
        }
    }

    /// The registered `'static` implementation for this kind.
    pub fn codec(self) -> &'static dyn ExpCodec {
        match self {
            CodecKind::Huffman => &HUFFMAN,
            CodecKind::Bdi => &BDI,
            CodecKind::Raw => &RAW,
        }
    }
}

/// A compressed exponent block from any registered codec: the common
/// currency between codecs, the flit packer, and the sim's CR tables.
#[derive(Clone, Debug)]
pub struct CodedBlock {
    /// Which codec produced `bytes` (decode dispatches on this; on the
    /// wire it travels as the [`CODEC_TAG_BITS`] tag).
    pub kind: CodecKind,
    /// Serialized payload, MSB-first (any codec-specific headers
    /// included).
    pub bytes: Vec<u8>,
    /// Exact bit length (excludes byte-alignment padding).
    pub bits: usize,
    /// Number of exponents encoded.
    pub count: usize,
    /// Optional integrity seal (ISSUE 6): CRC-16 of `bytes`, set by
    /// [`sealed`](CodedBlock::sealed). `None` keeps pre-v3 blocks and
    /// every byte-identity pin untouched; `Some` makes every registered
    /// codec's decode verify before touching the payload.
    pub crc: Option<u16>,
}

impl CodedBlock {
    /// Compression ratio vs raw 8-bit exponents (headers included) —
    /// Table 2's headline metric. Empty blocks report 1.0.
    pub fn ratio(&self) -> f64 {
        if self.bits == 0 {
            return 1.0;
        }
        (self.count as f64 * 8.0) / self.bits as f64
    }

    /// Seal the block: stamp the CRC-16 of the payload bytes so decode
    /// verifies integrity first. Idempotent on an unmodified block.
    pub fn sealed(mut self) -> Self {
        self.crc = Some(crc16(&self.bytes));
        self
    }

    /// Verify the seal, if any. Unsealed blocks pass vacuously; a sealed
    /// block whose payload no longer matches returns
    /// [`Error::Corrupt`]`{block: 0, lane: 0}`.
    pub fn verify(&self) -> Result<()> {
        match self.crc {
            Some(c) if crc16(&self.bytes) != c => {
                Err(Error::Corrupt { block: 0, lane: 0 })
            }
            _ => Ok(()),
        }
    }
}

/// A lossless exponent-stream codec.
///
/// Contract:
/// * `decode(encode(x)) == x` for every non-empty byte stream `x`;
/// * `encode` fills [`CodedBlock::kind`] with [`ExpCodec::kind`], and
///   `decode` rejects a block whose `kind` does not match (no silent
///   cross-codec misparse);
/// * hostile `bits`/`count` metadata is bounded **before** any
///   `count`-sized allocation (same hardening as
///   `huffman::decompress_exponents`'s count-header guard).
pub trait ExpCodec: Sync {
    /// The registry entry this codec implements.
    fn kind(&self) -> CodecKind;

    /// Compress an exponent stream into a self-describing block.
    fn encode(&self, exponents: &[u8]) -> Result<CodedBlock>;

    /// Losslessly invert [`encode`].
    ///
    /// [`encode`]: ExpCodec::encode
    fn decode(&self, block: &CodedBlock) -> Result<Vec<u8>>;

    /// The Table 2 coding ratio for `exponents` under this codec. The
    /// default encodes and reads [`CodedBlock::ratio`]; backends override
    /// where the paper reports a header-excluded number.
    fn coding_ratio(&self, exponents: &[u8]) -> f64 {
        if exponents.is_empty() {
            return 1.0;
        }
        self.encode(exponents).map(|b| b.ratio()).unwrap_or(1.0)
    }
}

/// Shared decode gate: kind dispatch check, then the integrity seal.
/// Every registered codec's `decode` flows through here, so a sealed
/// block is verified on all three paths before any payload bit is read.
fn check_kind(codec: &dyn ExpCodec, block: &CodedBlock) -> Result<()> {
    if block.kind != codec.kind() {
        return Err(Error::InvalidParameter(format!(
            "codec mismatch: {} block handed to the {} codec",
            block.kind.name(),
            codec.kind().name()
        )));
    }
    block.verify()
}

// --- Huffman (LEXI) --------------------------------------------------------

/// The LEXI canonical-Huffman codec via the §Perf batch engine.
pub struct HuffmanCodec;
/// Registry instance behind [`CodecKind::Huffman`].
pub static HUFFMAN: HuffmanCodec = HuffmanCodec;

impl ExpCodec for HuffmanCodec {
    fn kind(&self) -> CodecKind {
        CodecKind::Huffman
    }

    /// Exactly [`huffman::compress_exponents`]: per-stream codebook,
    /// serialized header + count + batch-encoded payload. Bit-identical
    /// to the direct call.
    fn encode(&self, exponents: &[u8]) -> Result<CodedBlock> {
        let block = huffman::compress_exponents(exponents)?;
        Ok(CodedBlock {
            kind: CodecKind::Huffman,
            bytes: block.bytes,
            bits: block.bits,
            count: block.count,
            crc: None,
        })
    }

    fn decode(&self, block: &CodedBlock) -> Result<Vec<u8>> {
        check_kind(self, block)?;
        let out = huffman::decompress_bits(&block.bytes, block.bits)?;
        if out.len() != block.count {
            return Err(Error::InvalidParameter(format!(
                "block metadata claims {} symbols, stream header carried {}",
                block.count,
                out.len()
            )));
        }
        Ok(out)
    }
}

// --- BDI -------------------------------------------------------------------

/// The Table 2 base–delta–immediate baseline.
pub struct BdiCodec;
/// Registry instance behind [`CodecKind::Bdi`].
pub static BDI: BdiCodec = BdiCodec;

impl ExpCodec for BdiCodec {
    fn kind(&self) -> CodecKind {
        CodecKind::Bdi
    }

    fn encode(&self, exponents: &[u8]) -> Result<CodedBlock> {
        let block = bdi::compress(exponents);
        Ok(CodedBlock {
            kind: CodecKind::Bdi,
            bytes: block.bytes,
            bits: block.bits,
            count: block.count,
            crc: None,
        })
    }

    fn decode(&self, block: &CodedBlock) -> Result<Vec<u8>> {
        check_kind(self, block)?;
        let out = bdi::decompress_bits(&block.bytes, block.bits)?;
        if out.len() != block.count {
            return Err(Error::InvalidParameter(format!(
                "block metadata claims {} symbols, stream header carried {}",
                block.count,
                out.len()
            )));
        }
        Ok(out)
    }

    /// Table 2 reports BDI's *pure* coding ratio (count header excluded).
    fn coding_ratio(&self, exponents: &[u8]) -> f64 {
        bdi::coding_ratio(exponents)
    }
}

// --- Raw -------------------------------------------------------------------

/// 8-bit passthrough: `bytes` is the exponent stream verbatim.
pub struct RawCodec;
/// Registry instance behind [`CodecKind::Raw`].
pub static RAW: RawCodec = RawCodec;

impl ExpCodec for RawCodec {
    fn kind(&self) -> CodecKind {
        CodecKind::Raw
    }

    fn encode(&self, exponents: &[u8]) -> Result<CodedBlock> {
        Ok(CodedBlock {
            kind: CodecKind::Raw,
            bytes: exponents.to_vec(),
            bits: exponents.len() * 8,
            count: exponents.len(),
            crc: None,
        })
    }

    fn decode(&self, block: &CodedBlock) -> Result<Vec<u8>> {
        check_kind(self, block)?;
        if block.bits != block.count * 8 || block.bytes.len() * 8 < block.bits {
            return Err(Error::InvalidParameter(format!(
                "raw block geometry inconsistent: {} bits / {} count / {} bytes",
                block.bits,
                block.count,
                block.bytes.len()
            )));
        }
        Ok(block.bytes[..block.count].to_vec())
    }

    fn coding_ratio(&self, _exponents: &[u8]) -> f64 {
        1.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest::check;

    fn sample(seed: u64, n: usize) -> Vec<u8> {
        let mut rng = crate::prng::Rng::new(seed);
        (0..n)
            .map(|_| crate::bf16::Bf16::from_f32(rng.normal_with(0.0, 0.05) as f32).exponent())
            .collect()
    }

    #[test]
    fn wire_tags_roundtrip_and_reject_reserved() {
        for kind in CodecKind::ALL {
            assert_eq!(CodecKind::from_wire_tag(kind.wire_tag()).unwrap(), kind);
            assert_eq!(CodecKind::parse(kind.name()).unwrap(), kind);
            assert_eq!(kind.codec().kind(), kind);
        }
        assert!(CodecKind::from_wire_tag(3).is_err());
        assert!(CodecKind::parse("zstd").is_err());
    }

    /// The ISSUE 3 acceptance gate: Huffman through the trait must be
    /// byte-identical to the direct `compress_exponents` path.
    #[test]
    fn huffman_via_trait_is_byte_identical() {
        for seed in [1u64, 7, 42] {
            let exps = sample(seed, 20_000);
            let direct = huffman::compress_exponents(&exps).unwrap();
            let via = CodecKind::Huffman.codec().encode(&exps).unwrap();
            assert_eq!(via.bytes, direct.bytes);
            assert_eq!(via.bits, direct.bits);
            assert_eq!(via.count, direct.count);
            assert_eq!(via.ratio(), direct.ratio());
            assert_eq!(
                CodecKind::Huffman.codec().decode(&via).unwrap(),
                huffman::decompress_exponents(&direct).unwrap()
            );
        }
    }

    #[test]
    fn bdi_via_trait_matches_direct() {
        let exps = sample(3, 10_000);
        let direct = bdi::compress(&exps);
        let via = CodecKind::Bdi.codec().encode(&exps).unwrap();
        assert_eq!(via.bytes, direct.bytes);
        assert_eq!(via.bits, direct.bits);
        assert_eq!(CodecKind::Bdi.codec().decode(&via).unwrap(), exps);
        // Table 2 semantics: header-excluded ratio.
        assert_eq!(
            CodecKind::Bdi.codec().coding_ratio(&exps),
            bdi::coding_ratio(&exps)
        );
    }

    #[test]
    fn prop_all_codecs_roundtrip() {
        check("ExpCodec roundtrip", 120, |g| {
            let n = g.usize(1..2500);
            let data = if g.bool(0.6) {
                let a = g.usize(1..48);
                g.skewed_bytes(n, a)
            } else {
                g.vec(n, |g| g.u8())
            };
            for kind in CodecKind::ALL {
                let codec = kind.codec();
                let block = codec.encode(&data).unwrap();
                assert_eq!(block.kind, kind);
                assert_eq!(block.count, data.len());
                assert_eq!(codec.decode(&block).unwrap(), data, "{kind:?}");
            }
        });
    }

    #[test]
    fn kind_mismatch_rejected() {
        let data = sample(9, 512);
        let huff = CodecKind::Huffman.codec().encode(&data).unwrap();
        let err = CodecKind::Bdi.codec().decode(&huff).unwrap_err();
        assert!(matches!(err, Error::InvalidParameter(_)), "{err:?}");
        let raw = CodecKind::Raw.codec().encode(&data).unwrap();
        assert!(CodecKind::Huffman.codec().decode(&raw).is_err());
    }

    #[test]
    fn coding_ratios_order_like_table2() {
        // LEXI > BDI > Raw on realistic concentrated exponent streams.
        let exps = sample(42, 100_000);
        let lexi = CodecKind::Huffman.codec().coding_ratio(&exps);
        let bdi_r = CodecKind::Bdi.codec().coding_ratio(&exps);
        let raw = CodecKind::Raw.codec().coding_ratio(&exps);
        assert!(lexi > bdi_r, "lexi {lexi} vs bdi {bdi_r}");
        assert!(bdi_r > 1.0, "bdi {bdi_r}");
        assert_eq!(raw, 1.0);
    }

    #[test]
    fn raw_block_geometry_validated() {
        let block = CodedBlock {
            kind: CodecKind::Raw,
            bytes: vec![1, 2, 3],
            bits: 4096, // claims more bits than the buffer holds
            count: 512,
            crc: None,
        };
        assert!(CodecKind::Raw.codec().decode(&block).is_err());
    }

    #[test]
    fn sealed_blocks_roundtrip_and_catch_corruption() {
        // ISSUE 6: every registered codec verifies the seal on decode.
        let data = sample(11, 2048);
        for kind in CodecKind::ALL {
            let codec = kind.codec();
            let sealed = codec.encode(&data).unwrap().sealed();
            assert!(sealed.crc.is_some());
            assert_eq!(codec.decode(&sealed).unwrap(), data, "{kind:?}");
            // Any payload byte flip is caught before decoding starts.
            let mut dirty = sealed.clone();
            dirty.bytes[dirty.bytes.len() / 2] ^= 0x40;
            assert_eq!(
                codec.decode(&dirty).unwrap_err(),
                Error::Corrupt { block: 0, lane: 0 },
                "{kind:?}"
            );
            // Unsealed blocks keep today's behavior: no verification.
            let plain = codec.encode(&data).unwrap();
            assert!(plain.crc.is_none());
            assert!(plain.verify().is_ok());
        }
    }
}
