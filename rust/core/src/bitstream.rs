//! MSB-first bit-level writer/reader.
//!
//! All LEXI codecs serialize through this module. MSB-first ordering is
//! chosen because canonical Huffman decode proceeds by numeric comparison
//! of left-aligned code prefixes — the same convention the multi-stage LUT
//! decoder hardware uses (paper §4.4).
//!
//! Robustness (ISSUE 6 audit): `get`/`peek`/`skip` return typed
//! [`Error::BitstreamExhausted`] on reads past the advertised length —
//! the `debug_assert!`s below guard *internal* invariants (callers
//! pre-checking `remaining()`), never wire-input validity, so corrupted
//! input cannot abort a release build.

use crate::error::{Error, Result};

/// Append-only bit writer.
///
/// Hot-path design (§Perf): bits accumulate MSB-first in a 64-bit
/// register; whole bytes spill to the backing vec only when the register
/// holds ≥ 8 bits. One `put` is a shift+or plus an amortized byte spill —
/// no per-bit loop.
#[derive(Clone, Debug, Default)]
pub struct BitWriter {
    buf: Vec<u8>,
    /// Pending bits, right-aligned.
    acc: u64,
    /// Number of valid bits in `acc` (always < 8 after `put`).
    nbits: u32,
}

impl BitWriter {
    /// New empty writer.
    pub fn new() -> Self {
        Self::default()
    }

    /// Total bits written so far.
    #[inline]
    pub fn len_bits(&self) -> usize {
        self.buf.len() * 8 + self.nbits as usize
    }

    /// Write the low `n` bits of `value`, MSB first. `n` ≤ 56.
    #[inline]
    pub fn put(&mut self, value: u64, n: u32) {
        debug_assert!(n <= 56, "put() supports up to 56 bits per call");
        debug_assert!(n == 64 || value < (1u64 << n), "value {value} overflows {n} bits");
        // nbits < 8 on entry, so nbits + n ≤ 63: no overflow.
        self.acc = (self.acc << n) | value;
        self.nbits += n;
        if self.nbits >= 8 {
            self.spill();
        }
    }

    /// Spill every whole accumulated byte in one word-sized step (§Perf):
    /// two shifts + one `extend_from_slice` instead of the former per-byte
    /// loop, so an 8-byte drain costs one memcpy. Kept out of line so the
    /// common no-spill `put` stays a branch over a shift+or.
    #[inline]
    fn spill(&mut self) {
        // nbits ∈ 8..=63 here ⇒ whole ∈ 8..=56, both shifts in range.
        let whole = self.nbits & !7;
        let rem = self.nbits - whole;
        // Keep the low `rem` bits; left-align the `whole` bits above them.
        let word = ((self.acc >> rem) << (64 - whole)).to_be_bytes();
        self.buf.extend_from_slice(&word[..(whole / 8) as usize]);
        self.nbits = rem;
    }

    /// Pre-reserve backing capacity for `bits` more bits (§Perf: the batch
    /// encoder sizes the buffer once from `CodeBook::payload_bits` instead
    /// of growing it amortized).
    pub fn reserve_bits(&mut self, bits: u64) {
        self.buf.reserve((bits as usize).div_ceil(8));
    }

    /// Write a single bit.
    #[inline]
    pub fn put_bit(&mut self, bit: bool) {
        self.put(bit as u64, 1);
    }

    /// Zero-pad to a byte boundary.
    pub fn align_byte(&mut self) {
        if self.nbits != 0 {
            self.put(0, 8 - self.nbits);
        }
    }

    /// Zero-pad so total length is a multiple of `n` bits (flit alignment).
    pub fn pad_to_multiple(&mut self, n: usize) {
        let len = self.len_bits();
        let rem = len % n;
        if rem != 0 {
            let mut pad = n - rem;
            while pad > 0 {
                let chunk = pad.min(56) as u32;
                self.put(0, chunk);
                pad -= chunk as usize;
            }
        }
    }

    /// Consume the writer, returning the backing bytes (zero-padded tail).
    pub fn into_bytes(mut self) -> Vec<u8> {
        if self.nbits != 0 {
            let pad = 8 - self.nbits;
            self.buf.push((self.acc << pad) as u8);
            self.nbits = 0;
        }
        self.buf
    }

    /// Borrow the whole bytes spilled so far (excludes a partial tail byte).
    pub fn as_bytes(&self) -> &[u8] {
        &self.buf
    }
}

/// MSB-first bit reader over a byte slice.
#[derive(Clone, Debug)]
pub struct BitReader<'a> {
    buf: &'a [u8],
    /// Next bit offset.
    pos: usize,
    /// Total readable bits (callers may clamp below `buf.len()*8`).
    len_bits: usize,
}

impl<'a> BitReader<'a> {
    /// Reader over all bits of `buf`.
    pub fn new(buf: &'a [u8]) -> Self {
        BitReader {
            buf,
            pos: 0,
            len_bits: buf.len() * 8,
        }
    }

    /// Reader over the first `len_bits` bits of `buf`.
    pub fn with_len(buf: &'a [u8], len_bits: usize) -> Self {
        debug_assert!(len_bits <= buf.len() * 8);
        BitReader {
            buf,
            pos: 0,
            len_bits,
        }
    }

    /// Current bit offset.
    #[inline]
    pub fn pos(&self) -> usize {
        self.pos
    }

    /// Bits remaining.
    #[inline]
    pub fn remaining(&self) -> usize {
        self.len_bits - self.pos
    }

    /// Read `n` bits MSB-first. Errors if the stream is exhausted.
    #[inline]
    pub fn get(&mut self, n: u32) -> Result<u64> {
        if (n as usize) > self.remaining() {
            return Err(Error::BitstreamExhausted {
                offset: self.pos,
                needed: n as usize - self.remaining(),
            });
        }
        let v = self.peek_unchecked(n);
        self.pos += n as usize;
        Ok(v)
    }

    /// Read a single bit.
    #[inline]
    pub fn get_bit(&mut self) -> Result<bool> {
        Ok(self.get(1)? == 1)
    }

    /// Peek up to `n` bits without consuming; if fewer remain, the result is
    /// left-aligned as if the stream were zero-extended. Used by the LUT
    /// decoder model, which always latches a full window.
    #[inline]
    pub fn peek_zeroext(&self, n: u32) -> u64 {
        let avail = self.remaining().min(n as usize) as u32;
        let v = self.peek_unchecked(avail);
        v << (n - avail)
    }

    /// Advance without reading (after a peek-based decode).
    #[inline]
    pub fn skip(&mut self, n: u32) -> Result<()> {
        if (n as usize) > self.remaining() {
            return Err(Error::BitstreamExhausted {
                offset: self.pos,
                needed: n as usize - self.remaining(),
            });
        }
        self.pos += n as usize;
        Ok(())
    }

    /// `(buffer, bit position, readable bit length)` — the raw parts a
    /// batch decoder builds its [`BitRefill`] window from. The caller is
    /// responsible for re-syncing with [`skip`] after consuming.
    ///
    /// [`skip`]: BitReader::skip
    #[inline]
    pub fn raw_parts(&self) -> (&'a [u8], usize, usize) {
        (self.buf, self.pos, self.len_bits)
    }

    #[inline]
    fn peek_unchecked(&self, n: u32) -> u64 {
        debug_assert!(n <= 57, "peek window limited by the u64 gather");
        if n == 0 {
            return 0;
        }
        let byte = self.pos / 8;
        let bit = (self.pos % 8) as u32;
        // Fast path (§Perf): one unaligned big-endian u64 load covers the
        // window whenever ≥8 bytes remain; the tail falls back to a gather.
        let window = if byte + 8 <= self.buf.len() {
            let arr: [u8; 8] = self.buf[byte..byte + 8]
                .try_into()
                .expect("slice is 8 bytes");
            u64::from_be_bytes(arr)
        } else {
            let mut w = 0u64;
            for i in 0..8 {
                let b = *self.buf.get(byte + i).unwrap_or(&0) as u64;
                w = (w << 8) | b;
            }
            w
        };
        (window << bit) >> (64 - n)
    }
}

/// Refill-based bit window over a byte slice (§Perf) — the batch
/// decoder's register file.
///
/// Invariants:
///
/// * `bitbuf` is **left-aligned**: its top `navail` bits are the next
///   unconsumed stream bits; every bit below them is zero. Consuming
///   shifts left (zeros in from the right).
/// * **Tail semantics**: once the loaded bytes run out, reads see zeros;
///   but when `len_bits` clamps mid-buffer, real buffer bytes *beyond*
///   `len_bits` are still loaded into the window (unlike
///   [`BitReader::peek_zeroext`], which zero-extends past `len_bits`).
///   Callers must therefore gate every consume on [`remaining`] — the
///   canonical decoder does, and its class-aligned comparisons make
///   successful decodes independent of those trailing bits; only the
///   *details* of an error (offset/needed/variant) may differ from the
///   zero-extended view.
///
/// [`remaining`]: BitRefill::remaining
/// * A [`refill`] tops the window up to ≥ 57 valid bits whenever unread
///   bytes remain, with a single unaligned big-endian `u64` load on the
///   fast path; after it, any codeword + escape byte (≤ 39 bits) decodes
///   without touching memory again.
/// * `pos()` is the absolute bit offset, so callers can re-sync an outer
///   [`BitReader`] and report precise error offsets.
///
/// [`refill`]: BitRefill::refill
#[derive(Clone, Debug)]
pub struct BitRefill<'a> {
    buf: &'a [u8],
    /// Next byte to load.
    byte_pos: usize,
    /// Left-aligned window of loaded-but-unconsumed bits.
    bitbuf: u64,
    /// Valid bit count at the top of `bitbuf`.
    navail: u32,
    /// Total readable bits of `buf` (callers may clamp mid-byte).
    len_bits: usize,
}

impl<'a> BitRefill<'a> {
    /// Window over `buf`, starting at absolute bit `start`, reading at
    /// most the first `len_bits` bits.
    pub fn new(buf: &'a [u8], start: usize, len_bits: usize) -> Self {
        debug_assert!(start <= len_bits && len_bits <= buf.len() * 8);
        let mut s = BitRefill {
            buf,
            byte_pos: start / 8,
            bitbuf: 0,
            navail: 0,
            len_bits,
        };
        s.refill();
        // Pre-consume the intra-byte offset. If start is mid-byte the
        // byte exists, so the refill loaded ≥ 8 bits.
        let sub = (start % 8) as u32;
        s.bitbuf <<= sub;
        s.navail -= sub;
        s
    }

    /// Absolute bit position consumed so far.
    #[inline]
    pub fn pos(&self) -> usize {
        self.byte_pos * 8 - self.navail as usize
    }

    /// Bits remaining before `len_bits`.
    #[inline]
    pub fn remaining(&self) -> usize {
        self.len_bits - self.pos()
    }

    /// Valid bits currently in the window.
    #[inline]
    pub fn navail(&self) -> u32 {
        self.navail
    }

    /// The left-aligned window (top [`navail`] bits valid, rest zero).
    ///
    /// [`navail`]: BitRefill::navail
    #[inline]
    pub fn window(&self) -> u64 {
        self.bitbuf
    }

    /// Refill only when fewer than `bits` are loaded — the hot-loop
    /// cadence gate (decoders ensure 40 bits per visit: worst codeword +
    /// escape byte ≤ 39, and a multi-symbol LUT probe ≤ `LUT_BITS`).
    #[inline]
    pub fn ensure(&mut self, bits: u32) {
        if self.navail < bits {
            self.refill();
        }
    }

    /// Top the window up to ≥ 57 valid bits, or to end-of-buffer.
    #[inline]
    pub fn refill(&mut self) {
        if self.byte_pos + 8 <= self.buf.len() {
            // Fast path: one unaligned big-endian load covers the top-up.
            let arr: [u8; 8] = self.buf[self.byte_pos..self.byte_pos + 8]
                .try_into()
                .expect("slice is 8 bytes");
            let w = u64::from_be_bytes(arr);
            // Whole bytes that fit above the valid region (0, 8, ..., 64).
            let add = (64 - self.navail) & !7;
            if add > 0 {
                // Mask w down to its top `add` bits so nothing leaks into
                // the zero region below `navail + add`.
                let chunk = if add == 64 { w } else { (w >> (64 - add)) << (64 - add) };
                self.bitbuf |= chunk >> self.navail;
                self.navail += add;
                self.byte_pos += (add / 8) as usize;
            }
        } else {
            // Tail: per-byte loads of whatever real bytes remain.
            while self.navail <= 56 && self.byte_pos < self.buf.len() {
                self.bitbuf |= (self.buf[self.byte_pos] as u64) << (56 - self.navail);
                self.navail += 8;
                self.byte_pos += 1;
            }
        }
    }

    /// Consume `n` bits. The caller must have checked `n ≤ remaining()`;
    /// after a [`refill`], `navail ≥ 57` or the stream tail is fully
    /// loaded, so `n ≤ remaining()` implies `n ≤ navail`.
    ///
    /// [`refill`]: BitRefill::refill
    #[inline]
    pub fn consume(&mut self, n: u32) {
        debug_assert!(n as usize <= self.remaining(), "consume past stream end");
        debug_assert!(n <= self.navail, "consume past loaded window");
        self.bitbuf <<= n;
        self.navail -= n;
    }
}

/// Struct-of-arrays register file: `N` concurrent [`BitRefill`]-style
/// windows over one shared buffer (§Perf) — the lockstep lane decoder's
/// state.
///
/// Every lane obeys the [`BitRefill`] invariants (left-aligned window,
/// top `navail` bits valid, consuming shifts left). Two deliberate
/// differences from holding `N` separate `BitRefill`s:
///
/// * State lives in **parallel arrays** (`window`, `byte_pos`, `navail`,
///   `end_bits` per lane), so the lockstep round-robin loop in
///   [`batch`] reads and writes `window[l]`/`navail[l]` for `N`
///   independent lanes back-to-back — the `N` table lookups have no
///   data dependence on each other and pipeline in the CPU.
/// * All lanes share **one buffer** with per-lane `(start, end)` bit
///   spans, so a refill of a mid-stream lane may load bytes belonging
///   to the *next* lane into the window. This is the same "real bytes
///   beyond the clamp" tail semantics `BitRefill` documents: every
///   consume must be gated on [`remaining`], and the canonical
///   decoder's class-aligned comparisons make successful decodes
///   independent of those trailing bits (only error *details* can
///   differ from a zero-extended view).
///
/// [`batch`]: crate::batch
/// [`remaining`]: LaneWindows::remaining
#[derive(Clone, Debug)]
pub struct LaneWindows<'a> {
    buf: &'a [u8],
    /// Next byte to load, per lane.
    byte_pos: Vec<usize>,
    /// Left-aligned windows of loaded-but-unconsumed bits.
    window: Vec<u64>,
    /// Valid bit count at the top of each window.
    navail: Vec<u32>,
    /// Absolute end bit of each lane's readable span.
    end_bits: Vec<usize>,
}

impl<'a> LaneWindows<'a> {
    /// Windows over `buf`, one per `(start_bit, end_bit)` span. Spans are
    /// absolute bit offsets and may touch (lane payloads are typically
    /// byte-aligned back-to-back); `start ≤ end ≤ buf.len() * 8` each.
    pub fn new(buf: &'a [u8], spans: &[(usize, usize)]) -> Self {
        let n = spans.len();
        let mut w = LaneWindows {
            buf,
            byte_pos: Vec::with_capacity(n),
            window: Vec::with_capacity(n),
            navail: Vec::with_capacity(n),
            end_bits: Vec::with_capacity(n),
        };
        for (l, &(start, end)) in spans.iter().enumerate() {
            debug_assert!(start <= end && end <= buf.len() * 8);
            w.byte_pos.push(start / 8);
            w.window.push(0);
            w.navail.push(0);
            w.end_bits.push(end);
            w.refill(l);
            // Pre-consume the intra-byte offset; if start is mid-byte the
            // byte exists, so the refill loaded ≥ 8 bits.
            let sub = (start % 8) as u32;
            w.window[l] <<= sub;
            w.navail[l] -= sub;
        }
        w
    }

    /// Number of lanes.
    #[inline]
    pub fn lanes(&self) -> usize {
        self.end_bits.len()
    }

    /// Absolute bit position lane `l` has consumed up to.
    #[inline]
    pub fn pos(&self, l: usize) -> usize {
        self.byte_pos[l] * 8 - self.navail[l] as usize
    }

    /// Bits remaining in lane `l`'s span.
    #[inline]
    pub fn remaining(&self, l: usize) -> usize {
        self.end_bits[l] - self.pos(l)
    }

    /// Valid bits currently in lane `l`'s window.
    #[inline]
    pub fn navail(&self, l: usize) -> u32 {
        self.navail[l]
    }

    /// Lane `l`'s left-aligned window (top [`navail`] bits valid).
    ///
    /// [`navail`]: LaneWindows::navail
    #[inline]
    pub fn window(&self, l: usize) -> u64 {
        self.window[l]
    }

    /// Refill lane `l` only when fewer than `bits` are loaded (same
    /// cadence gate as [`BitRefill::ensure`]).
    #[inline]
    pub fn ensure(&mut self, l: usize, bits: u32) {
        if self.navail[l] < bits {
            self.refill(l);
        }
    }

    /// Grouped [`ensure`] over lanes `l0 .. l0 + g` (ISSUE 8): one SWAR
    /// packed compare flags every lane of the group below the cadence
    /// threshold, then only the flagged lanes refill. Exactly the lanes
    /// `ensure` would refill do — `navail ≤ 64` and `bits < 128` keep
    /// the byte-wise compare exact — so per-lane state after a grouped
    /// ensure is bit-identical to `g` scalar ensures (property-pinned
    /// below and mirrored in `tools/logic_check.py` §[14]).
    ///
    /// [`ensure`]: LaneWindows::ensure
    #[inline]
    pub fn ensure_group(&mut self, l0: usize, g: usize, bits: u32) {
        debug_assert!(g >= 1 && g <= crate::swar::GROUP);
        debug_assert!(l0 + g <= self.lanes());
        debug_assert!(bits < 128, "SWAR compare threshold must stay below 128");
        let packed = crate::swar::pack_bytes(&self.navail[l0..l0 + g]);
        let mask = crate::swar::bytes_below(packed, bits as u8);
        for j in crate::swar::FlaggedLanes(mask & crate::swar::group_mask(g)) {
            self.refill(l0 + j);
        }
    }

    /// Top lane `l`'s window up to ≥ 57 valid bits, or to end-of-buffer.
    /// Same two-path load as [`BitRefill::refill`].
    #[inline]
    pub fn refill(&mut self, l: usize) {
        let byte_pos = self.byte_pos[l];
        let navail = self.navail[l];
        if byte_pos + 8 <= self.buf.len() {
            let arr: [u8; 8] = self.buf[byte_pos..byte_pos + 8]
                .try_into()
                .expect("slice is 8 bytes");
            let w = u64::from_be_bytes(arr);
            let add = (64 - navail) & !7;
            if add > 0 {
                let chunk = if add == 64 { w } else { (w >> (64 - add)) << (64 - add) };
                self.window[l] |= chunk >> navail;
                self.navail[l] = navail + add;
                self.byte_pos[l] = byte_pos + (add / 8) as usize;
            }
        } else {
            while self.navail[l] <= 56 && self.byte_pos[l] < self.buf.len() {
                self.window[l] |=
                    (self.buf[self.byte_pos[l]] as u64) << (56 - self.navail[l]);
                self.navail[l] += 8;
                self.byte_pos[l] += 1;
            }
        }
    }

    /// Consume `n` bits from lane `l`. Caller gates on [`remaining`], as
    /// with [`BitRefill::consume`].
    ///
    /// [`remaining`]: LaneWindows::remaining
    #[inline]
    pub fn consume(&mut self, l: usize, n: u32) {
        debug_assert!(n as usize <= self.remaining(l), "consume past lane end");
        debug_assert!(n <= self.navail[l], "consume past loaded window");
        self.window[l] <<= n;
        self.navail[l] -= n;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest::check;

    #[test]
    fn roundtrip_simple() {
        let mut w = BitWriter::new();
        w.put(0b101, 3);
        w.put(0xff, 8);
        w.put(0, 1);
        w.put(0b11, 2);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.get(3).unwrap(), 0b101);
        assert_eq!(r.get(8).unwrap(), 0xff);
        assert_eq!(r.get(1).unwrap(), 0);
        assert_eq!(r.get(2).unwrap(), 0b11);
    }

    #[test]
    fn len_bits_counts() {
        let mut w = BitWriter::new();
        assert_eq!(w.len_bits(), 0);
        w.put(1, 1);
        assert_eq!(w.len_bits(), 1);
        w.put(0, 7);
        assert_eq!(w.len_bits(), 8);
        w.put(0x1ff, 9);
        assert_eq!(w.len_bits(), 17);
    }

    #[test]
    fn pad_to_multiple_pads() {
        let mut w = BitWriter::new();
        w.put(1, 5);
        w.pad_to_multiple(128);
        assert_eq!(w.len_bits(), 128);
        w.put(1, 1);
        w.pad_to_multiple(128);
        assert_eq!(w.len_bits(), 256);
    }

    #[test]
    fn exhaustion_is_reported() {
        let bytes = [0xabu8];
        let mut r = BitReader::new(&bytes);
        r.get(5).unwrap();
        let err = r.get(5).unwrap_err();
        assert!(matches!(err, Error::BitstreamExhausted { .. }));
    }

    #[test]
    fn peek_zeroext_pads_with_zeros() {
        let bytes = [0b1010_0000u8];
        let mut r = BitReader::new(&bytes);
        r.skip(4).unwrap();
        // 4 bits remain (0000); peeking 8 zero-extends.
        assert_eq!(r.peek_zeroext(8), 0);
        let bytes2 = [0b1111_1111u8];
        let mut r2 = BitReader::new(&bytes2);
        r2.skip(4).unwrap();
        assert_eq!(r2.peek_zeroext(8), 0b1111_0000);
    }

    #[test]
    fn prop_roundtrip_random_fields() {
        check("bitstream roundtrip", 200, |g| {
            let n = g.usize(1..200);
            let fields: Vec<(u64, u32)> = (0..n)
                .map(|_| {
                    let bits = g.usize(1..33) as u32;
                    let val = g.u64(0..1u64 << bits);
                    (val, bits)
                })
                .collect();
            let mut w = BitWriter::new();
            for &(v, b) in &fields {
                w.put(v, b);
            }
            let total = w.len_bits();
            let bytes = w.into_bytes();
            let mut r = BitReader::with_len(&bytes, total);
            for &(v, b) in &fields {
                assert_eq!(r.get(b).unwrap(), v);
            }
            assert_eq!(r.remaining(), 0);
        });
    }

    #[test]
    fn prop_refill_matches_reader() {
        check("refill window == reader bits", 150, |g| {
            let n = g.usize(1..120);
            let bytes = g.vec(n, |g| g.u8());
            let len_bits = g.usize(1..bytes.len() * 8 + 1);
            let start = g.usize(0..len_bits + 1);
            let mut rf = BitRefill::new(&bytes, start, len_bits);
            let mut rd = BitReader::with_len(&bytes, len_bits);
            rd.skip(start as u32).unwrap();
            assert_eq!(rf.pos(), start);
            assert_eq!(rf.remaining(), rd.remaining());
            while rf.remaining() > 0 {
                if rf.navail() < 40 {
                    rf.refill();
                }
                let take = g.usize(1..rf.remaining().min(32) + 1) as u32;
                let want = rd.get(take).unwrap();
                let got = rf.window() >> (64 - take);
                assert_eq!(got, want, "at bit {}", rf.pos());
                rf.consume(take);
            }
            assert_eq!(rf.pos(), len_bits);
        });
    }

    #[test]
    fn prop_lane_windows_match_per_lane_refills() {
        check("LaneWindows == N independent BitRefills", 120, |g| {
            let nbytes = g.usize(8..160);
            let bytes = g.vec(nbytes, |g| g.u8());
            let lanes = g.usize(1..9);
            // Carve the buffer into `lanes` contiguous spans (some may be
            // empty), mimicking back-to-back lane payloads.
            let total_bits = bytes.len() * 8;
            let mut cuts: Vec<usize> = (0..lanes - 1)
                .map(|_| g.usize(0..total_bits + 1))
                .collect();
            cuts.sort_unstable();
            cuts.insert(0, 0);
            cuts.push(total_bits);
            let spans: Vec<(usize, usize)> =
                cuts.windows(2).map(|w| (w[0], w[1])).collect();
            let mut lw = LaneWindows::new(&bytes, &spans);
            let mut refs: Vec<BitRefill> = spans
                .iter()
                .map(|&(s, e)| BitRefill::new(&bytes, s, e))
                .collect();
            // Round-robin consumption: both views must agree bit-for-bit
            // at every step, even when a lane's refill loads bytes that
            // belong to its neighbour.
            let mut live = true;
            while live {
                live = false;
                for l in 0..lanes {
                    if lw.remaining(l) == 0 {
                        assert_eq!(refs[l].remaining(), 0, "lane {l}");
                        continue;
                    }
                    live = true;
                    if lw.navail(l) < 40 {
                        lw.refill(l);
                    }
                    if refs[l].navail() < 40 {
                        refs[l].refill();
                    }
                    assert_eq!(lw.pos(l), refs[l].pos(), "lane {l}");
                    assert_eq!(lw.remaining(l), refs[l].remaining(), "lane {l}");
                    let take = g.usize(1..lw.remaining(l).min(32) + 1) as u32;
                    let want = refs[l].window() >> (64 - take);
                    let got = lw.window(l) >> (64 - take);
                    assert_eq!(got, want, "lane {l} at bit {}", lw.pos(l));
                    lw.consume(l, take);
                    refs[l].consume(take);
                }
            }
        });
    }

    #[test]
    fn prop_ensure_group_is_bit_identical_to_scalar_ensures() {
        // ISSUE 8: the SWAR grouped refill gate must leave *exactly* the
        // state g scalar `ensure` calls leave — same windows, same
        // navail, same byte cursors — across random spans, group sizes,
        // thresholds, and interleaved consumption.
        check("ensure_group == per-lane ensure", 120, |g| {
            let nbytes = g.usize(8..200);
            let bytes = g.vec(nbytes, |g| g.u8());
            let lanes = g.usize(1..9);
            let total_bits = bytes.len() * 8;
            let mut cuts: Vec<usize> = (0..lanes - 1)
                .map(|_| g.usize(0..total_bits + 1))
                .collect();
            cuts.sort_unstable();
            cuts.insert(0, 0);
            cuts.push(total_bits);
            let spans: Vec<(usize, usize)> =
                cuts.windows(2).map(|w| (w[0], w[1])).collect();
            let mut grouped = LaneWindows::new(&bytes, &spans);
            let mut scalar = LaneWindows::new(&bytes, &spans);
            for _ in 0..60 {
                let l0 = g.usize(0..lanes);
                let gsz = g.usize(1..(lanes - l0).min(crate::swar::GROUP) + 1);
                let bits = g.usize(1..65) as u32;
                grouped.ensure_group(l0, gsz, bits);
                for l in l0..l0 + gsz {
                    scalar.ensure(l, bits);
                }
                for l in 0..lanes {
                    assert_eq!(grouped.window(l), scalar.window(l), "lane {l} window");
                    assert_eq!(grouped.navail(l), scalar.navail(l), "lane {l} navail");
                    assert_eq!(grouped.pos(l), scalar.pos(l), "lane {l} pos");
                    assert_eq!(
                        grouped.remaining(l),
                        scalar.remaining(l),
                        "lane {l} remaining"
                    );
                }
                // Interleave consumption so later ensures see mixed
                // navail levels, the shape the lockstep loop produces.
                let l = g.usize(0..lanes);
                let can = grouped.remaining(l).min(grouped.navail(l) as usize);
                if can > 0 {
                    let take = g.usize(1..can + 1) as u32;
                    grouped.consume(l, take);
                    scalar.consume(l, take);
                }
            }
        });
    }

    #[test]
    fn prop_peek_matches_get() {
        check("peek==get", 100, |g| {
            let bytes = g.vec(32, |g| g.u8());
            let mut r1 = BitReader::new(&bytes);
            let mut r2 = BitReader::new(&bytes);
            while r1.remaining() >= 16 {
                let n = g.usize(1..17) as u32;
                let peeked = r1.peek_zeroext(n);
                assert_eq!(peeked, r2.get(n).unwrap());
                r1.skip(n).unwrap();
            }
        });
    }
}
