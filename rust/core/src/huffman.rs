//! Canonical Huffman coding over the BF16 exponent alphabet — the LEXI
//! algorithm (paper §4.2–§4.4), software reference implementation.
//!
//! Key properties mirrored from the paper's hardware design:
//!
//! * The primary alphabet is capped at **32 symbols** (profiling shows fewer
//!   than 32 distinct exponents in practice); rarer exponents go through a
//!   reserved **escape code** followed by the raw 8-bit exponent.
//! * The escape codeword is the **all-ones** code — in a canonical complete
//!   prefix code, the numerically-last codeword of the maximum length is a
//!   run of ones, so placing ESC last in canonical order yields it
//!   construction-free. The paper quotes a 24-bit worst-case escape; we
//!   enforce this by building **length-limited** codes (package–merge) with
//!   `max_len = 24`.
//! * Codebooks are per-layer and piggybacked: a compact header (symbol,
//!   length) list prefixes each compressed stream, enough for the receiver
//!   to rebuild the identical canonical code.
//!
//! Robustness (ISSUE 6 audit): every decode routine in this module
//! returns typed [`Error`] variants on malformed or corrupted input —
//! truncated streams die as `BitstreamExhausted`, unknown codewords as
//! `InvalidCodeword`, hostile count headers are bounded before any
//! allocation. No decode path panics or silently truncates; CRC-based
//! *detection* of in-transit corruption lives one layer up, in
//! [`crate::integrity`] / the `LaneStream` v3 format.

use crate::batch::BatchEncoder;
use crate::bitstream::{BitReader, BitRefill, BitWriter};
use crate::error::{Error, Result};
use crate::lut::{self, MultiDecodeTable};
use crate::pool;
use crate::stats::Histogram;

/// Default alphabet cap (paper §4.2.2: "the primary pipeline is designed
/// for this 32-entry range").
pub const MAX_SYMBOLS: usize = 32;
/// Default maximum code length (paper §4.2.2: reserved 24-bit escape).
pub const MAX_CODE_LEN: u32 = 24;

/// Symbol id reserved for the escape code in canonical pair listings.
pub const ESC_SYMBOL: u16 = 256;
/// Internal alias.
const ESC: u16 = ESC_SYMBOL;

/// One assigned codeword.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Code {
    /// Right-aligned code bits.
    pub bits: u32,
    /// Code length in bits (1..=MAX_CODE_LEN).
    pub len: u32,
}

/// A canonical Huffman codebook over ≤32 exponent symbols plus ESC.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CodeBook {
    /// Per-exponent codes; `None` means "encode via escape".
    codes: [Option<Code>; 256],
    /// The escape codeword (all ones at its length).
    esc: Code,
    /// (symbol, len) pairs in canonical order, for serialization.
    canonical: Vec<(u16, u32)>,
    /// §Perf: per-exponent packed `(wire bits, wire length)` with the
    /// escape + raw byte pre-folded, so the encode hot loop is a single
    /// indexed `put`.
    packed: [(u64, u32); 256],
}

impl CodeBook {
    /// Build a length-limited canonical codebook from an exponent histogram.
    ///
    /// The `max_symbols` most frequent exponents get dedicated codes; all
    /// others use ESC + 8 raw bits. ESC participates in the tree with a
    /// weight equal to the total escaped mass (or 1 if none), so its length
    /// adapts to how often it is used.
    pub fn from_histogram(hist: &Histogram, max_symbols: usize, max_len: u32) -> Result<Self> {
        if hist.total == 0 {
            return Err(Error::EmptyHistogram);
        }
        if max_symbols == 0 || max_symbols > 256 {
            return Err(Error::InvalidParameter(format!(
                "max_symbols {max_symbols} out of range 1..=256"
            )));
        }
        // max_len must accommodate max_symbols+1 distinct codes.
        if (max_len as usize) < usize::BITS as usize
            && (1usize << max_len) < max_symbols + 1
        {
            return Err(Error::InvalidParameter(format!(
                "max_len {max_len} too small for {max_symbols} symbols"
            )));
        }

        let sorted = hist.sorted_symbols();
        let (head, tail) = sorted.split_at(sorted.len().min(max_symbols));
        let escaped_mass: u64 = tail.iter().map(|&(_, c)| c).sum();

        // Weighted symbol set: top symbols + ESC.
        let mut syms: Vec<(u16, u64)> = head.iter().map(|&(s, c)| (s as u16, c)).collect();
        syms.push((ESC, escaped_mass.max(1)));

        let mut lengths = package_merge(&syms, max_len)?;

        // The reserved escape must be the all-ones codeword (paper §4.2.2),
        // i.e. the canonically-last code, i.e. ESC must hold the maximum
        // length. When escapes are frequent, Huffman may give ESC a shorter
        // code; swapping lengths with a max-length symbol keeps the code
        // complete (Kraft sum unchanged) at a negligible optimality cost —
        // the hardware design assumes escapes are rare anyway.
        let esc_idx = syms.len() - 1;
        let lmax = *lengths.iter().max().expect("non-empty");
        if lengths[esc_idx] < lmax {
            let j = lengths
                .iter()
                .position(|&l| l == lmax)
                .expect("max exists");
            lengths.swap(esc_idx, j);
        }

        // Canonical order: (length asc, ESC last within its length, symbol asc).
        let mut canonical: Vec<(u16, u32)> = syms
            .iter()
            .map(|&(s, _)| s)
            .zip(lengths.iter().copied())
            .collect();
        canonical.sort_by_key(|&(s, len)| (len, s == ESC, s));
        // ESC has (weakly) minimal weight → (weakly) maximal length → with
        // the tie-break above it sorts last, so canonical assignment gives
        // it the all-ones codeword.
        debug_assert_eq!(canonical.last().map(|&(s, _)| s), Some(ESC));

        let mut codes: [Option<Code>; 256] = [None; 256];
        let mut esc = Code { bits: 0, len: 0 };
        let mut next = 0u32;
        let mut prev_len = canonical[0].1;
        for &(sym, len) in &canonical {
            next <<= len - prev_len;
            prev_len = len;
            let code = Code { bits: next, len };
            if sym == ESC {
                esc = code;
            } else {
                codes[sym as usize] = Some(code);
            }
            next += 1;
        }
        // Completeness check: last code of length L must be all ones.
        debug_assert_eq!(esc.bits, (1u32 << esc.len) - 1, "ESC must be all-ones");

        Ok(CodeBook {
            packed: Self::pack_lut(&codes, esc),
            codes,
            esc,
            canonical,
        })
    }

    /// Build the packed encode LUT: dedicated codes as-is, escaped symbols
    /// as `ESC-code ++ raw byte` (≤ 32 bits total).
    fn pack_lut(codes: &[Option<Code>; 256], esc: Code) -> [(u64, u32); 256] {
        std::array::from_fn(|sym| match codes[sym] {
            Some(c) => (c.bits as u64, c.len),
            None => (
                ((esc.bits as u64) << 8) | sym as u64,
                esc.len + 8,
            ),
        })
    }

    /// Convenience: paper defaults (32 symbols, 24-bit cap).
    pub fn lexi_default(hist: &Histogram) -> Result<Self> {
        Self::from_histogram(hist, MAX_SYMBOLS, MAX_CODE_LEN)
    }

    /// The code for `symbol`, if it has a dedicated entry.
    #[inline]
    pub fn code(&self, symbol: u8) -> Option<Code> {
        self.codes[symbol as usize]
    }

    /// The escape codeword.
    #[inline]
    pub fn escape(&self) -> Code {
        self.esc
    }

    /// Number of dedicated (non-ESC) symbols.
    pub fn num_symbols(&self) -> usize {
        self.canonical.len() - 1
    }

    /// Maximum code length used (including ESC).
    pub fn max_len(&self) -> u32 {
        self.canonical.iter().map(|&(_, l)| l).max().unwrap_or(0)
    }

    /// Canonical (symbol, length) pairs, ESC encoded as symbol id 256.
    pub fn canonical_pairs(&self) -> &[(u16, u32)] {
        &self.canonical
    }

    /// Encode one exponent (dedicated code or ESC + raw byte).
    #[inline]
    pub fn encode_symbol(&self, symbol: u8, w: &mut BitWriter) {
        let (bits, len) = self.packed[symbol as usize];
        w.put(bits, len);
    }

    /// Exact compressed size in bits of `symbol` under this codebook.
    #[inline]
    pub fn symbol_bits(&self, symbol: u8) -> u32 {
        self.packed[symbol as usize].1
    }

    /// Exact compressed payload size (bits) for a whole histogram.
    pub fn payload_bits(&self, hist: &Histogram) -> u64 {
        let mut bits = 0u64;
        for s in 0..256 {
            let c = hist.counts[s];
            if c > 0 {
                bits += c * self.symbol_bits(s as u8) as u64;
            }
        }
        bits
    }

    /// Serialize the codebook header: `count:6`, then per entry
    /// `{is_esc:1, symbol:8, len:5}`. ~13 bits/entry, ≤ 55 bytes total.
    pub fn write_header(&self, w: &mut BitWriter) {
        w.put(self.canonical.len() as u64, 6);
        for &(sym, len) in &self.canonical {
            w.put((sym == ESC) as u64, 1);
            w.put((sym & 0xff) as u64, 8);
            w.put(len as u64, 5);
        }
    }

    /// Header size in bits.
    pub fn header_bits(&self) -> u64 {
        6 + 14 * self.canonical.len() as u64
    }

    /// Deserialize a codebook header written by [`write_header`].
    ///
    /// [`write_header`]: CodeBook::write_header
    pub fn read_header(r: &mut BitReader) -> Result<Self> {
        let count = r.get(6)? as usize;
        if count < 1 {
            return Err(Error::MalformedCodebook("zero entries".into()));
        }
        let mut canonical = Vec::with_capacity(count);
        for i in 0..count {
            let is_esc = r.get(1)? == 1;
            let symbol = r.get(8)? as u16;
            let len = r.get(5)? as u32;
            if len == 0 || len > 31 {
                return Err(Error::MalformedCodebook(format!(
                    "entry {i}: length {len} out of range"
                )));
            }
            canonical.push((if is_esc { ESC } else { symbol }, len));
        }
        Self::from_canonical(canonical)
    }

    /// Build a codebook from validated canonical `(symbol, length)` pairs,
    /// with the escape encoded as symbol id 256 and placed last. This is
    /// the constructor the hardware tree-builder model (`lexi-hw`) uses:
    /// hardware emits code *lengths*, canonical assignment makes the bits.
    pub fn from_canonical(canonical: Vec<(u16, u32)>) -> Result<Self> {
        if canonical.is_empty() {
            return Err(Error::MalformedCodebook("zero entries".into()));
        }
        let mut prev_len = 0u32;
        let mut esc_seen = false;
        for (i, &(sym, len)) in canonical.iter().enumerate() {
            if len == 0 || len > 31 {
                return Err(Error::MalformedCodebook(format!(
                    "entry {i}: length {len} out of range"
                )));
            }
            if len < prev_len {
                return Err(Error::MalformedCodebook(
                    "entries not in canonical length order".into(),
                ));
            }
            prev_len = len;
            if sym == ESC {
                if esc_seen {
                    return Err(Error::MalformedCodebook("duplicate ESC".into()));
                }
                esc_seen = true;
            } else if sym > 255 {
                return Err(Error::MalformedCodebook(format!(
                    "symbol id {sym} out of range"
                )));
            }
        }
        if !esc_seen {
            return Err(Error::MalformedCodebook("missing ESC".into()));
        }
        if canonical.last().map(|&(s, _)| s) != Some(ESC) {
            return Err(Error::MalformedCodebook("ESC not last".into()));
        }
        // Kraft check: canonical assignment requires a complete code.
        let kraft: u64 = canonical.iter().map(|&(_, l)| 1u64 << (32 - l)).sum();
        if kraft != 1u64 << 32 {
            return Err(Error::MalformedCodebook(format!(
                "Kraft sum {} ≠ 1 (incomplete or overfull code)",
                kraft as f64 / (1u64 << 32) as f64
            )));
        }

        let mut codes: [Option<Code>; 256] = [None; 256];
        let mut esc = Code { bits: 0, len: 0 };
        let mut next = 0u32;
        let mut prev = canonical[0].1;
        for &(sym, len) in &canonical {
            next <<= len - prev;
            prev = len;
            let code = Code { bits: next, len };
            if sym == ESC {
                esc = code;
            } else {
                if codes[sym as usize].is_some() {
                    return Err(Error::MalformedCodebook(format!(
                        "duplicate symbol {sym}"
                    )));
                }
                codes[sym as usize] = Some(code);
            }
            next += 1;
        }
        Ok(CodeBook {
            packed: Self::pack_lut(&codes, esc),
            codes,
            esc,
            canonical,
        })
    }

    /// Build a codebook from per-symbol lengths (ESC = id 256), sorting
    /// into canonical order internally.
    pub fn from_lengths(pairs: &[(u16, u32)]) -> Result<Self> {
        let mut canonical = pairs.to_vec();
        canonical.sort_by_key(|&(s, len)| (len, s == ESC, s));
        Self::from_canonical(canonical)
    }

    /// Build a canonical decoder (software mirror of the multi-stage LUT).
    pub fn decoder(&self) -> CanonicalDecoder {
        CanonicalDecoder::new(self)
    }

    /// Build a canonical decoder with the **multi-symbol decode LUT**
    /// attached (§Perf, ISSUE 4): block decodes emit up to
    /// [`lut::LUT_MAX_SYMS`] exponents per table probe, bit-identical to
    /// [`decoder`]'s output. Costs a `2^LUT_BITS`-probe table fill on top
    /// of the scalar tables — build once per stream/transfer; short
    /// blocks should stay on [`decoder`]
    /// (see [`lut::LUT_DECODE_MIN_SYMBOLS`]).
    ///
    /// [`decoder`]: CodeBook::decoder
    pub fn lut_decoder(&self) -> CanonicalDecoder {
        let mut dec = CanonicalDecoder::new(self);
        let table = MultiDecodeTable::from_decoder(&dec);
        dec.multi = Some(table);
        dec
    }

    /// The decoder a block of `symbols` should use: [`lut_decoder`] when
    /// the block amortizes the table fill ([`lut::amortizes_fill`]),
    /// else the plain [`decoder`]. The single home of the
    /// threshold policy — `decompress_bits`, `flit::unpack`, and the
    /// lane codec all route through it.
    ///
    /// [`decoder`]: CodeBook::decoder
    /// [`lut_decoder`]: CodeBook::lut_decoder
    pub fn decoder_for(&self, symbols: usize) -> CanonicalDecoder {
        if lut::amortizes_fill(symbols) {
            self.lut_decoder()
        } else {
            self.decoder()
        }
    }
}

/// Canonical Huffman decoder using per-length first-code tables, fronted
/// by a direct lookup table for short codes (§Perf) — the standard
/// software realization; `lexi-hw` models the LUT pipeline against this
/// oracle.
#[derive(Clone, Debug)]
pub struct CanonicalDecoder {
    /// For each length L: (first_code << (32-L)) left-aligned threshold.
    first_code_aligned: Vec<u64>,
    /// For each length L: index of first symbol of that length.
    first_index: Vec<usize>,
    /// Symbols in canonical order (ESC = 256).
    symbols: Vec<u16>,
    /// Lengths present, ascending.
    lengths: Vec<u32>,
    esc_len: u32,
    /// Direct table indexed by the next `FAST_BITS` bits: packed
    /// `(symbol << 8) | len`, or `FAST_MISS` for codes longer than
    /// `FAST_BITS` (fall back to the length-class walk).
    fast: Vec<u32>,
    /// Multi-symbol decode LUT (ISSUE 4): present on decoders built via
    /// [`CodeBook::lut_decoder`]; block decodes then drain up to
    /// [`lut::LUT_MAX_SYMS`] symbols per probe. `None` keeps the scalar
    /// fast table only (cheap build, the measurement baseline).
    multi: Option<MultiDecodeTable>,
}

/// Width of the fast direct-decode table (2^11 × 4 B = 8 KiB).
const FAST_BITS: u32 = 11;
/// Miss sentinel; also marks ESC patterns (the raw byte may extend past
/// the window) and codes longer than `FAST_BITS`.
pub(crate) const FAST_MISS: u32 = u32::MAX;

// The multi-symbol table ([`lut`]) reuses the fast table as its scratch
// classifier, so the two widths must agree.
const _: () = assert!(FAST_BITS == lut::LUT_BITS);

impl CanonicalDecoder {
    fn new(book: &CodeBook) -> Self {
        let mut first_code_aligned = Vec::new();
        let mut first_index = Vec::new();
        let mut lengths = Vec::new();
        let mut symbols = Vec::with_capacity(book.canonical.len());
        let mut next = 0u32;
        let mut prev_len = book.canonical[0].1;
        let mut fast = vec![FAST_MISS; 1 << FAST_BITS];
        for (i, &(sym, len)) in book.canonical.iter().enumerate() {
            next <<= len - prev_len;
            prev_len = len;
            if lengths.last() != Some(&len) {
                lengths.push(len);
                first_index.push(i);
                first_code_aligned.push((next as u64) << (32 - len));
            }
            symbols.push(sym);
            // Fill the fast table: every FAST_BITS pattern starting with
            // this codeword decodes to it (ESC excluded: it needs the raw
            // byte anyway, keep it on the slow path).
            if len <= FAST_BITS && sym != ESC {
                let lo = (next as usize) << (FAST_BITS - len);
                let hi = ((next as usize) + 1) << (FAST_BITS - len);
                let packed = ((sym as u32) << 8) | len;
                for slot in &mut fast[lo..hi] {
                    *slot = packed;
                }
            }
            next += 1;
        }
        CanonicalDecoder {
            first_code_aligned,
            first_index,
            symbols,
            lengths,
            esc_len: book.esc.len,
            fast,
            multi: None,
        }
    }

    /// The attached multi-symbol decode LUT, if this decoder was built
    /// with [`CodeBook::lut_decoder`]. The `lexi-hw` cycle model and the
    /// lockstep lane loop both probe it directly.
    #[inline]
    pub fn multi_table(&self) -> Option<&MultiDecodeTable> {
        self.multi.as_ref()
    }

    /// The single-symbol fast table — the multi-symbol LUT's scratch
    /// classifier ([`MultiDecodeTable::from_decoder`]).
    #[inline]
    pub(crate) fn fast_table(&self) -> &[u32] {
        &self.fast
    }

    /// Decode one exponent from the reader (resolving ESC to the raw byte).
    #[inline]
    pub fn decode(&self, r: &mut BitReader) -> Result<u8> {
        // Fast path: direct table on the next FAST_BITS bits.
        let probe = r.peek_zeroext(FAST_BITS) as usize;
        let hit = self.fast[probe];
        if hit != FAST_MISS {
            let len = hit & 0xff;
            if (r.remaining() as u32) >= len {
                r.skip(len)?;
                return Ok((hit >> 8) as u8);
            }
            // Too few bits left for this codeword: fall through so the
            // slow path reports the precise exhaustion error.
        }
        self.decode_slow(r)
    }

    /// Length-class walk for long codes, ESC, and stream-tail errors.
    fn decode_slow(&self, r: &mut BitReader) -> Result<u8> {
        // Left-aligned 32-bit window; compare against per-length thresholds
        // from the longest down — the window is within a length class iff
        // it is >= that class's first code and < the next class's.
        let window = r.peek_zeroext(32);
        let offset = r.pos();
        // Find the smallest length whose next-class threshold exceeds window.
        for k in 0..self.lengths.len() {
            let len = self.lengths[k];
            let upper = if k + 1 < self.lengths.len() {
                self.first_code_aligned[k + 1]
            } else {
                u64::MAX
            };
            if window < upper {
                if (r.remaining() as u32) < len {
                    return Err(Error::BitstreamExhausted {
                        offset,
                        needed: len as usize - r.remaining(),
                    });
                }
                let code = (window >> (32 - len)) as u32;
                let first = (self.first_code_aligned[k] >> (32 - len)) as u32;
                let idx = self.first_index[k] + (code - first) as usize;
                if idx >= self.symbols.len() {
                    return Err(Error::InvalidCodeword { offset });
                }
                r.skip(len)?;
                let sym = self.symbols[idx];
                if sym == ESC {
                    return Ok(r.get(8)? as u8);
                }
                return Ok(sym as u8);
            }
        }
        Err(Error::InvalidCodeword { offset })
    }

    /// The ESC code length (hardware sizing input).
    pub fn esc_len(&self) -> u32 {
        self.esc_len
    }

    /// Batch-decode exactly `out.len()` symbols from `r` (§Perf).
    ///
    /// Refill-based: a local 64-bit [`BitRefill`] window is topped up at
    /// most once per symbol (one unaligned load per ~2–4 short codes),
    /// the fast table resolves short codes against the window registers
    /// with no per-symbol bounds re-derivation, and symbols store
    /// directly into `out` — no `Vec::push`. `r` is advanced past
    /// everything consumed.
    ///
    /// Equivalence with repeated [`decode`]: every *successful* decode is
    /// bit-exact, and a stream that errors under one path errors under
    /// the other — but because [`BitRefill`] loads real buffer bytes past
    /// a mid-byte `len_bits` clamp where [`decode`] zero-extends, the
    /// error's offset/`needed` detail may differ on such tails.
    ///
    /// [`decode`]: CanonicalDecoder::decode
    pub fn decode_block_into(&self, r: &mut BitReader, out: &mut [u8]) -> Result<()> {
        let (buf, start, len_bits) = r.raw_parts();
        let mut s = BitRefill::new(buf, start, len_bits);
        match &self.multi {
            Some(table) => self.decode_block_multi(table, &mut s, out)?,
            None => {
                for slot in out.iter_mut() {
                    // 40 bits cover the worst case (31-bit ESC + 8 raw
                    // bits), so one refill per symbol suffices.
                    s.ensure(40);
                    *slot = self.decode_one(&mut s)?;
                }
            }
        }
        // Re-sync the outer reader (chunked: skip takes u32).
        let mut left = s.pos() - start;
        while left > 0 {
            let step = left.min(1 << 30) as u32;
            r.skip(step)?;
            left -= step as usize;
        }
        Ok(())
    }

    /// Multi-symbol block loop (ISSUE 4): one LUT probe emits up to
    /// [`lut::LUT_MAX_SYMS`] exponents. An entry is consumed only when it
    /// holds ≥ 1 symbol, the block still wants that many, and its bits
    /// fit `remaining()` — everything else (ESC-leading probes, long
    /// codes, stream tails) takes the scalar kernel, so output **and
    /// error details** are identical to the scalar loop.
    fn decode_block_multi(
        &self,
        table: &MultiDecodeTable,
        s: &mut BitRefill,
        out: &mut [u8],
    ) -> Result<()> {
        let mut i = 0;
        while i < out.len() {
            // One visit consumes ≤ max(LUT_BITS, 39) bits; the 40-bit
            // cadence of the scalar loop covers both arms.
            s.ensure(40);
            let e = table.entry(s.window());
            let n = MultiDecodeTable::count(e) as usize;
            let used = MultiDecodeTable::consumed(e);
            if n != 0 && n <= out.len() - i && used as usize <= s.remaining() {
                // Entry bytes 0..n are the decoded symbols in order.
                out[i..i + n].copy_from_slice(&e.to_le_bytes()[..n]);
                s.consume(used);
                i += n;
            } else {
                out[i] = self.decode_one(s)?;
                i += 1;
            }
        }
        Ok(())
    }

    /// One symbol off the refill window: fast-table probe, then the
    /// length-class walk. Mirrors [`decode`]/[`decode_slow`] exactly.
    ///
    /// [`decode`]: CanonicalDecoder::decode
    /// [`decode_slow`]: CanonicalDecoder::decode_slow
    #[inline]
    fn decode_one(&self, s: &mut BitRefill) -> Result<u8> {
        let (sym, used) = self.decode_from_window(s.window(), s.remaining(), s.pos())?;
        s.consume(used);
        Ok(sym)
    }

    /// Decode one symbol from a left-aligned 64-bit `window` holding
    /// `remaining` readable bits, **without touching any stream state**:
    /// returns `(symbol, consumed_bits)` and leaves the consume to the
    /// caller. `pos` is only used for error offsets.
    ///
    /// This is the single decode kernel behind both the refill block
    /// decoder ([`decode_block_into`]) and the lockstep multi-lane loop
    /// in [`batch`] — the SoA lane state there owns its windows, so the
    /// kernel must be pure. The caller guarantees the window holds ≥ 40
    /// valid bits or the stream tail fully loaded (one refill per symbol
    /// suffices: worst codeword + escape byte ≤ 39 bits).
    ///
    /// [`decode_block_into`]: CanonicalDecoder::decode_block_into
    /// [`batch`]: crate::batch
    #[inline]
    pub(crate) fn decode_from_window(
        &self,
        window: u64,
        remaining: usize,
        pos: usize,
    ) -> Result<(u8, u32)> {
        let probe = (window >> (64 - FAST_BITS)) as usize;
        let hit = self.fast[probe];
        if hit != FAST_MISS {
            let len = hit & 0xff;
            if remaining >= len as usize {
                return Ok(((hit >> 8) as u8, len));
            }
        }
        self.decode_from_window_slow(window, remaining, pos)
    }

    fn decode_from_window_slow(
        &self,
        window: u64,
        remaining: usize,
        pos: usize,
    ) -> Result<(u8, u32)> {
        // Same per-length-class comparison as `decode_slow`, against the
        // top 32 bits of the window. For any *valid* codeword all window
        // extensions stay inside its length class (class uppers are
        // aligned to the class's code granularity), so tail garbage
        // below `remaining` cannot flip a successful decode.
        let w32 = window >> 32;
        for k in 0..self.lengths.len() {
            let len = self.lengths[k];
            let upper = if k + 1 < self.lengths.len() {
                self.first_code_aligned[k + 1]
            } else {
                u64::MAX
            };
            if w32 < upper {
                if remaining < len as usize {
                    return Err(Error::BitstreamExhausted {
                        offset: pos,
                        needed: len as usize - remaining,
                    });
                }
                let code = (w32 >> (32 - len)) as u32;
                let first = (self.first_code_aligned[k] >> (32 - len)) as u32;
                let idx = self.first_index[k] + (code - first) as usize;
                if idx >= self.symbols.len() {
                    return Err(Error::InvalidCodeword { offset: pos });
                }
                let sym = self.symbols[idx];
                if sym == ESC {
                    if remaining < len as usize + 8 {
                        return Err(Error::BitstreamExhausted {
                            offset: pos + len as usize,
                            needed: len as usize + 8 - remaining,
                        });
                    }
                    let raw = ((window << len) >> 56) as u8;
                    return Ok((raw, len + 8));
                }
                return Ok((sym as u8, len));
            }
        }
        Err(Error::InvalidCodeword { offset: pos })
    }
}

/// Length-limited Huffman code lengths via the package–merge algorithm.
///
/// Returns one length per input symbol (same order), each ≤ `max_len`,
/// forming a complete prefix code of minimal weighted length.
fn package_merge(syms: &[(u16, u64)], max_len: u32) -> Result<Vec<u32>> {
    let n = syms.len();
    if n == 0 {
        return Err(Error::EmptyHistogram);
    }
    if n == 1 {
        // A single symbol still needs 1 bit to be decodable mid-stream.
        return Ok(vec![1]);
    }
    if (max_len as usize) < 63 && (1u128 << max_len) < n as u128 {
        return Err(Error::InvalidParameter(format!(
            "cannot fit {n} symbols in codes of ≤{max_len} bits"
        )));
    }

    // Package–merge: items are (weight, coin-set of original indices).
    // At each level we merge pairs ("package") and re-add the originals.
    #[derive(Clone)]
    struct Item {
        weight: u64,
        /// Count per original symbol index contributed by this item.
        members: Vec<u32>,
    }

    let originals: Vec<Item> = {
        let mut v: Vec<(usize, u64)> = syms.iter().map(|&(_, w)| w).enumerate().collect();
        v.sort_by_key(|&(i, w)| (w, i));
        v.into_iter()
            .map(|(i, w)| {
                let mut members = vec![0u32; n];
                members[i] = 1;
                Item { weight: w, members }
            })
            .collect()
    };

    let mut level: Vec<Item> = originals.clone();
    for _ in 1..max_len {
        // Package: pair adjacent items.
        let mut packages: Vec<Item> = Vec::with_capacity(level.len() / 2);
        let mut it = level.chunks_exact(2);
        for pair in &mut it {
            let mut members = pair[0].members.clone();
            for (m, o) in members.iter_mut().zip(&pair[1].members) {
                *m += o;
            }
            packages.push(Item {
                weight: pair[0].weight + pair[1].weight,
                members,
            });
        }
        // Merge with the originals (both sorted; stable merge).
        let mut merged = Vec::with_capacity(packages.len() + originals.len());
        let (mut i, mut j) = (0, 0);
        while i < originals.len() || j < packages.len() {
            let take_orig = match (originals.get(i), packages.get(j)) {
                (Some(a), Some(b)) => a.weight <= b.weight,
                (Some(_), None) => true,
                _ => false,
            };
            if take_orig {
                merged.push(originals[i].clone());
                i += 1;
            } else {
                merged.push(packages[j].clone());
                j += 1;
            }
        }
        level = merged;
    }

    // Take the first 2n-2 items; each appearance of symbol i adds 1 to its
    // code length.
    let mut lengths = vec![0u32; n];
    for item in level.iter().take(2 * n - 2) {
        for (idx, &c) in item.members.iter().enumerate() {
            lengths[idx] += c;
        }
    }
    debug_assert!(lengths.iter().all(|&l| l >= 1 && l <= max_len));
    // Kraft equality must hold for a minimal complete code.
    debug_assert_eq!(
        lengths.iter().map(|&l| 1u128 << (64 - l)).sum::<u128>(),
        1u128 << 64
    );
    Ok(lengths)
}

/// A self-contained compressed exponent block: codebook header + payload.
#[derive(Clone, Debug)]
pub struct EncodedExponents {
    /// Serialized bits: header then payload (MSB-first).
    pub bytes: Vec<u8>,
    /// Exact bit length (excludes byte-alignment padding).
    pub bits: usize,
    /// Number of exponents encoded.
    pub count: usize,
}

impl EncodedExponents {
    /// Compression ratio vs raw 8-bit exponents (header included).
    pub fn ratio(&self) -> f64 {
        (self.count as f64 * 8.0) / self.bits as f64
    }
}

/// Compress an exponent stream with a per-block codebook (the per-layer
/// boundary of §4.1 maps to one call per layer output).
pub fn compress_exponents(exponents: &[u8]) -> Result<EncodedExponents> {
    let hist = Histogram::from_bytes(exponents);
    let book = CodeBook::lexi_default(&hist)?;
    let mut w = BitWriter::new();
    // §Perf: exact capacity up front — the histogram prices the payload.
    w.reserve_bits(book.header_bits() + 32 + book.payload_bits(&hist));
    compress_with_book_into(exponents, &book, w)
}

/// Compress with an explicit codebook (e.g. one built from only the first
/// 512 samples, as the hardware does). Routed through the batch engine
/// ([`BatchEncoder`]); output is bit-identical to the scalar
/// per-symbol path.
pub fn compress_with_book(exponents: &[u8], book: &CodeBook) -> Result<EncodedExponents> {
    let mut w = BitWriter::new();
    // No histogram here: reserve a 2-bit/symbol estimate (realistic
    // streams land near it; worst case just re-grows).
    w.reserve_bits(book.header_bits() + 32 + exponents.len() as u64 * 2);
    compress_with_book_into(exponents, book, w)
}

fn compress_with_book_into(
    exponents: &[u8],
    book: &CodeBook,
    mut w: BitWriter,
) -> Result<EncodedExponents> {
    book.write_header(&mut w);
    w.put(exponents.len() as u64, 32);
    BatchEncoder::new(book).encode_block(exponents, &mut w);
    let bits = w.len_bits();
    Ok(EncodedExponents {
        bytes: w.into_bytes(),
        bits,
        count: exponents.len(),
    })
}

/// Decompress a block produced by [`compress_exponents`]. Routed through
/// the refill-based batch decoder ([`CanonicalDecoder::decode_block_into`]).
pub fn decompress_exponents(block: &EncodedExponents) -> Result<Vec<u8>> {
    decompress_bits(&block.bytes, block.bits)
}

/// Decompress from raw parts — the entry the [`ExpCodec`] registry uses
/// so a [`CodedBlock`] needn't be re-wrapped into [`EncodedExponents`].
///
/// [`ExpCodec`]: crate::codec::ExpCodec
/// [`CodedBlock`]: crate::codec::CodedBlock
pub fn decompress_bits(bytes: &[u8], bits: usize) -> Result<Vec<u8>> {
    let mut r = BitReader::with_len(bytes, bits.min(bytes.len() * 8));
    let book = CodeBook::read_header(&mut r)?;
    let count = r.get(32)? as usize;
    // Bound the untrusted count by the remaining payload before the
    // output allocation (every codeword is ≥ 1 bit) — same hardening as
    // LaneStream::validated_lanes; a hostile header cannot demand a
    // multi-gigabyte zero-fill from a tiny block.
    if count > r.remaining() {
        return Err(Error::InvalidParameter(format!(
            "block header claims {count} symbols but only {} payload bits remain",
            r.remaining()
        )));
    }
    // §Perf (ISSUE 4): blocks long enough to amortize the table fill
    // decode through the multi-symbol LUT; short blocks stay scalar.
    let dec = book.decoder_for(count);
    let mut out = vec![0u8; count];
    dec.decode_block_into(&mut r, &mut out)?;
    Ok(out)
}

/// Fixed shard size of the block-parallel codec (ISSUE 8), in symbols.
/// The partition depends only on the input length — **never on the
/// thread count** — which is what makes [`compress_exponents_par`]
/// byte-identical for every `T`. 64 Ki symbols is large enough that the
/// per-block codebook header (≤ ~120 bytes) costs < 0.2% of the
/// payload, and small enough that realistic layer outputs split into
/// many shards.
pub const PAR_BLOCK_SYMBOLS: usize = 1 << 16;

/// A block-parallel compressed stream: [`PAR_BLOCK_SYMBOLS`]-sized
/// shards, each a self-contained [`EncodedExponents`] (own codebook
/// header, so shards decode independently).
#[derive(Clone, Debug)]
pub struct ParEncoded {
    /// Total exponents across all blocks.
    pub count: usize,
    /// The per-shard blocks, in input order.
    pub blocks: Vec<EncodedExponents>,
}

impl ParEncoded {
    /// Compression ratio vs raw 8-bit exponents (all headers included).
    pub fn ratio(&self) -> f64 {
        let bits: usize = self.blocks.iter().map(|b| b.bits).sum();
        (self.count as f64 * 8.0) / bits.max(1) as f64
    }
}

/// Block-parallel [`compress_exponents`] (ISSUE 8): the input splits
/// into fixed [`PAR_BLOCK_SYMBOLS`] shards, each compressed (with its
/// own per-block codebook) on the [`pool`]. Deterministic and
/// thread-count invariant — the shard geometry is a pure function of
/// `exponents.len()`, and a shard's bytes are a pure function of its
/// slice. The surfaced error is the first failing block in input order.
///
/// This is a wall-clock path for bulk weight/KV streams; the
/// simulator's calibration keeps using the single-thread codec
/// (DESIGN.md §SIMD & sharded parallelism).
pub fn compress_exponents_par(exponents: &[u8], threads: usize) -> Result<ParEncoded> {
    if exponents.is_empty() {
        return Ok(ParEncoded {
            count: 0,
            blocks: Vec::new(),
        });
    }
    let shards = exponents.len().div_ceil(PAR_BLOCK_SYMBOLS);
    let results = pool::run_sharded(shards, threads, |s| {
        let lo = s * PAR_BLOCK_SYMBOLS;
        let hi = (lo + PAR_BLOCK_SYMBOLS).min(exponents.len());
        compress_exponents(&exponents[lo..hi])
    });
    let mut blocks = Vec::with_capacity(results.len());
    for r in results {
        // First error in block (= input) order.
        blocks.push(r?);
    }
    Ok(ParEncoded {
        count: exponents.len(),
        blocks,
    })
}

/// Block-parallel [`decompress_exponents`] (ISSUE 8): every shard
/// decodes independently on the [`pool`]; outputs concatenate in block
/// order on the caller's thread. Bit-identical to decompressing each
/// block sequentially, for every thread count; the surfaced error is
/// the first failing block in order, and a count mismatch between the
/// header and the decoded blocks is rejected, never padded.
pub fn decompress_exponents_par(enc: &ParEncoded, threads: usize) -> Result<Vec<u8>> {
    let results = pool::run_sharded(enc.blocks.len(), threads, |s| {
        decompress_exponents(&enc.blocks[s])
    });
    let mut out = Vec::with_capacity(enc.count);
    for r in results {
        out.extend_from_slice(&r?);
    }
    if out.len() != enc.count {
        return Err(Error::InvalidParameter(format!(
            "parallel stream header claims {} symbols but blocks decode to {}",
            enc.count,
            out.len()
        )));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest::check;

    fn book_of(bytes: &[u8]) -> CodeBook {
        CodeBook::lexi_default(&Histogram::from_bytes(bytes)).unwrap()
    }

    #[test]
    fn prop_par_roundtrip_and_thread_invariance() {
        // ISSUE 8: parallel compress/decompress round-trips, is
        // byte-identical across thread counts, and each block equals the
        // sequential compress_exponents of its own slice (the shard
        // geometry is T-independent by construction).
        check("par codec roundtrip + T-invariance", 12, |g| {
            let n = g.usize(1..PAR_BLOCK_SYMBOLS * 3);
            let data = if g.bool(0.7) {
                let a = g.usize(1..40);
                g.skewed_bytes(n, a)
            } else {
                g.vec(n, |g| g.u8())
            };
            let base = compress_exponents_par(&data, 1).unwrap();
            assert_eq!(base.count, data.len());
            assert_eq!(base.blocks.len(), data.len().div_ceil(PAR_BLOCK_SYMBOLS));
            for (s, blk) in base.blocks.iter().enumerate() {
                let lo = s * PAR_BLOCK_SYMBOLS;
                let hi = (lo + PAR_BLOCK_SYMBOLS).min(data.len());
                let seq = compress_exponents(&data[lo..hi]).unwrap();
                assert_eq!(blk.bytes, seq.bytes, "block {s} bytes");
                assert_eq!(blk.bits, seq.bits, "block {s} bits");
            }
            for t in [2usize, 8] {
                let par = compress_exponents_par(&data, t).unwrap();
                assert_eq!(par.blocks.len(), base.blocks.len(), "T={t}");
                for (s, (a, b)) in par.blocks.iter().zip(&base.blocks).enumerate() {
                    assert_eq!(a.bytes, b.bytes, "T={t} block {s}");
                }
            }
            for t in [1usize, 2, 8] {
                assert_eq!(
                    decompress_exponents_par(&base, t).unwrap(),
                    data,
                    "decode T={t}"
                );
            }
            assert!(base.ratio() > 0.0);
        });
    }

    #[test]
    fn par_empty_stream_roundtrips() {
        let enc = compress_exponents_par(&[], 8).unwrap();
        assert_eq!(enc.count, 0);
        assert!(enc.blocks.is_empty());
        assert_eq!(decompress_exponents_par(&enc, 8).unwrap(), Vec::<u8>::new());
    }

    #[test]
    fn par_corrupt_block_surfaces_first_in_order() {
        // Corrupt block 1 of 3: the surfaced error is block 1's own
        // sequential error, at every thread count — never block 2's, and
        // never wrong symbols.
        let data: Vec<u8> = (0..PAR_BLOCK_SYMBOLS * 2 + 17)
            .map(|i| 120 + (i % 5) as u8)
            .collect();
        let mut enc = compress_exponents_par(&data, 4).unwrap();
        assert_eq!(enc.blocks.len(), 3);
        enc.blocks[1].bits = enc.blocks[1].bits.saturating_sub(9);
        let want = decompress_exponents(&enc.blocks[1]).unwrap_err();
        for t in [1usize, 2, 8] {
            assert_eq!(
                decompress_exponents_par(&enc, t).unwrap_err(),
                want,
                "T={t}"
            );
        }
        // A forged count is rejected rather than padded or truncated.
        let mut forged = compress_exponents_par(&data, 2).unwrap();
        forged.count += 1;
        assert!(decompress_exponents_par(&forged, 2).is_err());
    }

    #[test]
    fn single_symbol_stream() {
        let data = vec![127u8; 100];
        let block = compress_exponents(&data).unwrap();
        assert_eq!(decompress_exponents(&block).unwrap(), data);
        // 1 bit per symbol + header + count.
        assert!(block.bits < 100 + 64 + 40);
    }

    #[test]
    fn two_symbol_stream() {
        let mut data = vec![126u8; 70];
        data.extend(vec![127u8; 30]);
        let block = compress_exponents(&data).unwrap();
        assert_eq!(decompress_exponents(&block).unwrap(), data);
    }

    #[test]
    fn escape_roundtrip() {
        // 40 distinct symbols forces 8 of them through ESC.
        let mut data = Vec::new();
        for s in 0..40u8 {
            for _ in 0..(40 - s) {
                data.push(s);
            }
        }
        let book = book_of(&data);
        assert_eq!(book.num_symbols(), 32);
        let block = compress_exponents(&data).unwrap();
        assert_eq!(decompress_exponents(&block).unwrap(), data);
    }

    #[test]
    fn esc_is_all_ones() {
        let data: Vec<u8> = (0..200u32).map(|i| (i % 7) as u8 * 3 + 100).collect();
        let book = book_of(&data);
        let esc = book.escape();
        assert_eq!(esc.bits, (1 << esc.len) - 1);
    }

    #[test]
    fn code_lengths_respect_cap() {
        // Fibonacci-ish weights produce deep unconstrained Huffman trees.
        let mut hist = Histogram::default();
        let (mut a, mut b) = (1u64, 1u64);
        for s in 0..30u8 {
            hist.add(s, a);
            let c = a + b;
            a = b;
            b = c;
        }
        let book = CodeBook::from_histogram(&hist, 32, 12).unwrap();
        assert!(book.max_len() <= 12, "max_len {}", book.max_len());
        // And still decodes.
        let data: Vec<u8> = (0..30u8).flat_map(|s| vec![s; 3]).collect();
        let block = compress_with_book(&data, &book).unwrap();
        assert_eq!(decompress_exponents(&block).unwrap(), data);
    }

    #[test]
    fn prefix_free_property() {
        check("codes are prefix-free", 100, |g| {
            let (n, a) = (g.usize(1..2000), g.usize(1..64));
            let data = g.skewed_bytes(n, a);
            let book = book_of(&data);
            let mut all: Vec<Code> = (0..=255u8).filter_map(|s| book.code(s)).collect();
            all.push(book.escape());
            for (i, a) in all.iter().enumerate() {
                for (j, b) in all.iter().enumerate() {
                    if i == j {
                        continue;
                    }
                    let (short, long) = if a.len <= b.len { (a, b) } else { (b, a) };
                    let prefix = long.bits >> (long.len - short.len);
                    assert!(
                        !(prefix == short.bits && a.len != b.len || a.bits == b.bits && a.len == b.len),
                        "prefix violation {a:?} {b:?}"
                    );
                }
            }
        });
    }

    #[test]
    fn prop_roundtrip_arbitrary_streams() {
        check("huffman roundtrip", 150, |g| {
            let n = g.usize(1..3000);
            // Mix of skewed and fully-random bytes exercises ESC heavily.
            let data = if g.bool(0.7) {
                { let a = g.usize(2..80); g.skewed_bytes(n, a) }
            } else {
                g.vec(n, |g| g.u8())
            };
            let block = compress_exponents(&data).unwrap();
            assert_eq!(decompress_exponents(&block).unwrap(), data);
        });
    }

    #[test]
    fn prop_header_roundtrip() {
        check("codebook header roundtrip", 100, |g| {
            let (n, a) = (g.usize(1..500), g.usize(1..40));
            let data = g.skewed_bytes(n, a);
            let book = book_of(&data);
            let mut w = BitWriter::new();
            book.write_header(&mut w);
            let bits = w.len_bits();
            let bytes = w.into_bytes();
            let mut r = BitReader::with_len(&bytes, bits);
            let back = CodeBook::read_header(&mut r).unwrap();
            assert_eq!(back, book);
        });
    }

    #[test]
    fn prop_compression_beats_entropy_bound_within_1bit() {
        check("huffman ≤ H+1 per symbol", 60, |g| {
            let (n, a) = (g.usize(256..4000), g.usize(2..30));
            let data = g.skewed_bytes(n, a);
            let hist = Histogram::from_bytes(&data);
            let book = CodeBook::lexi_default(&hist).unwrap();
            let payload = book.payload_bits(&hist) as f64;
            let bound = hist.entropy_bits() * data.len() as f64;
            assert!(
                payload <= bound + data.len() as f64 + 16.0,
                "payload {payload} vs bound {bound}"
            );
        });
    }

    #[test]
    fn gaussian_exponents_hit_paper_ratio() {
        // Table 2 reports ~3.1× exponent CR on LLM weights; Gaussian weights
        // with realistic σ should land in the same band (2.5–4×).
        use crate::bf16::Bf16;
        use crate::prng::Rng;
        let mut rng = Rng::new(2024);
        let exps: Vec<u8> = (0..200_000)
            .map(|_| Bf16::from_f32(rng.normal_with(0.0, 0.02) as f32).exponent())
            .collect();
        let block = compress_exponents(&exps).unwrap();
        let cr = block.ratio();
        assert!((2.2..4.5).contains(&cr), "CR {cr}");
        assert_eq!(decompress_exponents(&block).unwrap(), exps);
    }

    #[test]
    fn malformed_headers_rejected() {
        // Truncated stream.
        let data = vec![1u8, 2, 3];
        let block = compress_exponents(&data).unwrap();
        let mut r = BitReader::with_len(&block.bytes, 10);
        assert!(CodeBook::read_header(&mut r).is_err());
        // Garbage bits.
        let garbage = [0xffu8; 8];
        let mut r2 = BitReader::new(&garbage);
        assert!(CodeBook::read_header(&mut r2).is_err());
    }

    #[test]
    fn hostile_block_count_rejected_before_allocation() {
        // Forge the 32-bit count field to u32::MAX on a valid tiny block:
        // decompress must reject (count bounded by remaining payload
        // bits) instead of zero-filling a 4 GiB output first.
        let data = vec![5u8; 64];
        let block = compress_exponents(&data).unwrap();
        let book = {
            let mut r = BitReader::with_len(&block.bytes, block.bits);
            let b = CodeBook::read_header(&mut r).unwrap();
            assert_eq!(r.get(32).unwrap() as usize, data.len());
            b
        };
        let count_at = book.header_bits() as usize; // count field offset
        let mut forged = block.clone();
        // Overwrite the 32 bits at `count_at` with all-ones.
        for bit in count_at..count_at + 32 {
            forged.bytes[bit / 8] |= 0x80 >> (bit % 8);
        }
        let err = decompress_exponents(&forged).unwrap_err();
        assert!(matches!(err, Error::InvalidParameter(_)), "{err:?}");
    }

    #[test]
    fn empty_histogram_rejected() {
        assert_eq!(
            CodeBook::lexi_default(&Histogram::default()).unwrap_err(),
            Error::EmptyHistogram
        );
    }
}
