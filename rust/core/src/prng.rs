//! Deterministic pseudo-random number generation and distributions.
//!
//! The offline crate set has no `rand`, so this is a small, well-tested
//! substrate: SplitMix64 (seeding), xoshiro256** (bulk generation), and the
//! distributions the synthetic-tensor generators need — uniform, standard
//! normal (Box–Muller), Laplace, and Zipf (for synthetic token streams).
//!
//! Everything is seeded and reproducible: the same seed yields the same
//! traffic, the same histograms, and the same benchmark tables.

/// SplitMix64 — used to expand a single `u64` seed into xoshiro state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create from a seed.
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    /// Next 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — fast, high-quality general-purpose PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 per the xoshiro authors' recommendation.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Rng {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Next 32-bit output.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, 1)` with 53-bit precision.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)` (Lemire-style rejection-free for our use).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // 128-bit multiply keeps bias below 2^-64 — negligible for sims.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.uniform() * (hi - lo)
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Standard normal via Box–Muller (uses one value, discards the pair —
    /// simplicity beats the 2× speedup here; the weight generators are
    /// streaming and not on the request path).
    #[inline]
    pub fn normal(&mut self) -> f64 {
        // Avoid ln(0).
        let u1 = 1.0 - self.uniform();
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Normal with mean `mu` and standard deviation `sigma`.
    #[inline]
    pub fn normal_with(&mut self, mu: f64, sigma: f64) -> f64 {
        mu + sigma * self.normal()
    }

    /// Laplace(0, b) — trained LLM weights are empirically closer to Laplace
    /// than Gaussian in the tails, which slightly widens the exponent
    /// histogram; we support both so the entropy profiling can show the
    /// sensitivity.
    #[inline]
    pub fn laplace(&mut self, b: f64) -> f64 {
        let u = self.uniform() - 0.5;
        -b * u.signum() * (1.0 - 2.0 * u.abs()).ln()
    }

    /// Shuffle a slice in place (Fisher–Yates).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

/// Zipf sampler over `[0, n)` with exponent `s`, via precomputed CDF.
///
/// Used for synthetic token-id streams ("wt2-like"/"c4-like" corpora):
/// natural-language token frequencies are famously Zipfian.
#[derive(Clone, Debug)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    /// Build a sampler for `n` ranks with exponent `s` (s≈1 for text).
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0, "zipf needs at least one rank");
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let norm = acc;
        for c in &mut cdf {
            *c /= norm;
        }
        Zipf { cdf }
    }

    /// Sample a rank in `[0, n)`.
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.uniform();
        match self
            .cdf
            .binary_search_by(|c| c.partial_cmp(&u).expect("cdf is finite"))
        {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut rng = Rng::new(7);
        let mut sum = 0.0;
        const N: usize = 100_000;
        for _ in 0..N {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / N as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_is_in_range() {
        let mut rng = Rng::new(3);
        for n in [1u64, 2, 7, 100, 1 << 40] {
            for _ in 0..100 {
                assert!(rng.below(n) < n);
            }
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(11);
        const N: usize = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..N {
            let x = rng.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / N as f64;
        let var = sq / N as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn laplace_moments() {
        let mut rng = Rng::new(13);
        const N: usize = 200_000;
        let b = 2.0;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..N {
            let x = rng.laplace(b);
            sum += x;
            sq += x * x;
        }
        let mean = sum / N as f64;
        let var = sq / N as f64 - mean * mean; // expect 2 b² = 8
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 8.0).abs() < 0.3, "var {var}");
    }

    #[test]
    fn zipf_is_monotonically_less_frequent() {
        let mut rng = Rng::new(5);
        let z = Zipf::new(100, 1.1);
        let mut counts = vec![0usize; 100];
        for _ in 0..200_000 {
            counts[z.sample(&mut rng)] += 1;
        }
        // Rank 0 must dominate rank 10 which dominates rank 90.
        assert!(counts[0] > counts[10]);
        assert!(counts[10] > counts[90]);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(17);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
