//! BF16 (bfloat16) representation and field extraction.
//!
//! BF16 is the upper 16 bits of an IEEE-754 binary32:
//! `{sign:1, exponent:8, mantissa:7}`. LEXI never alters the numeric
//! semantics — it only transports the three fields separately, with the
//! exponent entropy-coded. This module is the single source of truth for
//! that field split (paper §3.1).

/// A bfloat16 value stored as its raw 16-bit pattern.
///
/// The wrapper is deliberately transparent: the codecs operate on the bit
/// pattern, and numeric conversions exist only for test oracles and
/// profiling.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Default)]
pub struct Bf16(pub u16);

impl Bf16 {
    /// Number of exponent bits in BF16 (same as FP32 — full dynamic range).
    pub const EXP_BITS: u32 = 8;
    /// Number of mantissa bits.
    pub const MANT_BITS: u32 = 7;

    /// Truncating conversion from `f32` (round-toward-zero on the mantissa).
    ///
    /// Matches the "drop the low 16 bits" framing used when profiling; the
    /// exponent field — all LEXI cares about — is identical under any
    /// rounding mode except at exact power-of-two boundaries.
    #[inline]
    pub fn from_f32(x: f32) -> Self {
        Bf16((x.to_bits() >> 16) as u16)
    }

    /// Round-to-nearest-even conversion from `f32` (what hardware matmul
    /// units and `jnp.bfloat16` casts do).
    #[inline]
    pub fn from_f32_rne(x: f32) -> Self {
        let bits = x.to_bits();
        // NaN must stay NaN: force a quiet-NaN pattern rather than risking
        // the rounding carry turning it into infinity.
        if x.is_nan() {
            return Bf16(((bits >> 16) as u16) | 0x0040);
        }
        let lsb = (bits >> 16) & 1;
        let rounded = bits.wrapping_add(0x7fff + lsb);
        Bf16((rounded >> 16) as u16)
    }

    /// Exact widening conversion to `f32`.
    #[inline]
    pub fn to_f32(self) -> f32 {
        f32::from_bits((self.0 as u32) << 16)
    }

    /// Sign bit (0 or 1).
    #[inline]
    pub fn sign(self) -> u8 {
        (self.0 >> 15) as u8
    }

    /// Biased 8-bit exponent field — the stream LEXI compresses.
    #[inline]
    pub fn exponent(self) -> u8 {
        ((self.0 >> 7) & 0xff) as u8
    }

    /// 7-bit mantissa field (transmitted verbatim; ~full entropy per Fig 1a).
    #[inline]
    pub fn mantissa(self) -> u8 {
        (self.0 & 0x7f) as u8
    }

    /// Reassemble a BF16 from its three fields. Inverse of the extractors.
    #[inline]
    pub fn from_fields(sign: u8, exponent: u8, mantissa: u8) -> Self {
        debug_assert!(sign <= 1, "sign must be a single bit");
        debug_assert!(mantissa <= 0x7f, "mantissa is 7 bits");
        Bf16(((sign as u16) << 15) | ((exponent as u16) << 7) | (mantissa as u16 & 0x7f))
    }
}

impl std::fmt::Debug for Bf16 {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "Bf16({:#06x} = {} | e={} m={:#04x})",
            self.0,
            self.to_f32(),
            self.exponent(),
            self.mantissa()
        )
    }
}

impl From<f32> for Bf16 {
    fn from(x: f32) -> Self {
        Bf16::from_f32_rne(x)
    }
}

impl From<Bf16> for f32 {
    fn from(x: Bf16) -> f32 {
        x.to_f32()
    }
}

/// The three field streams of a BF16 tensor, split for transport.
///
/// This is the logical payload of a LEXI transfer before entropy coding:
/// signs and mantissas go verbatim, `exponents` is what the Huffman codec
/// consumes.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FieldStreams {
    pub signs: Vec<u8>,
    pub exponents: Vec<u8>,
    pub mantissas: Vec<u8>,
}

impl FieldStreams {
    /// Split a BF16 slice into its per-field streams.
    pub fn split(values: &[Bf16]) -> Self {
        let mut s = FieldStreams {
            signs: Vec::with_capacity(values.len()),
            exponents: Vec::with_capacity(values.len()),
            mantissas: Vec::with_capacity(values.len()),
        };
        for &v in values {
            s.signs.push(v.sign());
            s.exponents.push(v.exponent());
            s.mantissas.push(v.mantissa());
        }
        s
    }

    /// Reassemble the original BF16 values. Lossless inverse of [`split`].
    ///
    /// [`split`]: FieldStreams::split
    pub fn join(&self) -> Vec<Bf16> {
        debug_assert_eq!(self.signs.len(), self.exponents.len());
        debug_assert_eq!(self.signs.len(), self.mantissas.len());
        self.signs
            .iter()
            .zip(&self.exponents)
            .zip(&self.mantissas)
            .map(|((&s, &e), &m)| Bf16::from_fields(s, e, m))
            .collect()
    }

    /// Number of values.
    pub fn len(&self) -> usize {
        self.exponents.len()
    }

    /// True if the stream holds no values.
    pub fn is_empty(&self) -> bool {
        self.exponents.is_empty()
    }
}

/// Extract only the exponent stream (the common profiling fast path).
pub fn exponents_of(values: &[Bf16]) -> Vec<u8> {
    values.iter().map(|v| v.exponent()).collect()
}

/// Interpret a little-endian byte buffer (e.g. a tensor fetched from PJRT)
/// as BF16 values.
pub fn bf16_from_le_bytes(bytes: &[u8]) -> Vec<Bf16> {
    bytes
        .chunks_exact(2)
        .map(|c| Bf16(u16::from_le_bytes([c[0], c[1]])))
        .collect()
}

/// Serialize BF16 values to little-endian bytes.
pub fn bf16_to_le_bytes(values: &[Bf16]) -> Vec<u8> {
    let mut out = Vec::with_capacity(values.len() * 2);
    for v in values {
        out.extend_from_slice(&v.0.to_le_bytes());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn field_roundtrip_all_patterns() {
        // Every 16-bit pattern must survive split→join exactly.
        for bits in 0..=u16::MAX {
            let v = Bf16(bits);
            let r = Bf16::from_fields(v.sign(), v.exponent(), v.mantissa());
            assert_eq!(v, r, "pattern {bits:#06x}");
        }
    }

    #[test]
    fn f32_widening_is_exact() {
        for bits in [0u16, 0x3f80, 0xbf80, 0x7f80, 0xff80, 0x0001, 0x4049] {
            let v = Bf16(bits);
            assert_eq!(Bf16::from_f32(v.to_f32()), v);
        }
    }

    #[test]
    fn known_values() {
        let one = Bf16::from_f32(1.0);
        assert_eq!(one.sign(), 0);
        assert_eq!(one.exponent(), 127);
        assert_eq!(one.mantissa(), 0);

        let neg_two = Bf16::from_f32(-2.0);
        assert_eq!(neg_two.sign(), 1);
        assert_eq!(neg_two.exponent(), 128);

        let half = Bf16::from_f32(0.5);
        assert_eq!(half.exponent(), 126);
    }

    #[test]
    fn rne_rounds_to_nearest() {
        // 1.0 + 2^-8 rounds down to 1.0 in bf16; 1.0 + 3*2^-9 rounds up.
        let x = 1.0f32 + 2.0f32.powi(-9);
        assert_eq!(Bf16::from_f32_rne(x).to_f32(), 1.0);
        let y = 1.0f32 + 3.0 * 2.0f32.powi(-9);
        assert!(Bf16::from_f32_rne(y).to_f32() > 1.0);
    }

    #[test]
    fn rne_preserves_nan() {
        let v = Bf16::from_f32_rne(f32::NAN);
        assert!(v.to_f32().is_nan());
    }

    #[test]
    fn streams_roundtrip() {
        let vals: Vec<Bf16> = (0..1000u32)
            .map(|i| Bf16::from_f32((i as f32 - 500.0) * 0.037))
            .collect();
        let s = FieldStreams::split(&vals);
        assert_eq!(s.join(), vals);
        assert_eq!(s.len(), vals.len());
    }

    #[test]
    fn byte_roundtrip() {
        let vals: Vec<Bf16> = (0..257u32).map(|i| Bf16(i as u16 * 251)).collect();
        let bytes = bf16_to_le_bytes(&vals);
        assert_eq!(bf16_from_le_bytes(&bytes), vals);
    }
}
