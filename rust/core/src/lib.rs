//! # lexi-core — BF16 exponent codecs and profiling substrate
//!
//! Software reference implementations of everything LEXI does to bits:
//!
//! * [`bf16`] — BF16 field extraction ({sign, exponent, mantissa}) and
//!   conversions; the profiling substrate of the paper's Fig. 1(a).
//! * [`stats`] — Shannon entropy, exponent histograms, distinct-value counts.
//! * [`bitstream`] — MSB-first bit-level reader/writer used by every codec.
//! * [`huffman`] — canonical Huffman over the ≤32-value exponent alphabet
//!   with the reserved all-ones escape code (paper §4.2.2), i.e. the LEXI
//!   algorithm itself, independent of its hardware realization.
//! * [`rle`], [`bdi`] — the paper's Table 2 baselines (run-length coding and
//!   base-delta-immediate).
//! * [`codec`] — the pluggable [`ExpCodec`](codec::ExpCodec) layer: one
//!   trait + [`CodecKind`](codec::CodecKind) registry/wire-tag over
//!   Huffman, BDI, and raw passthrough, so every consumer (flit, sim,
//!   CLI) swaps codecs without naming them.
//! * [`flit`] — flit-aligned packetization
//!   `{header, signs, mantissas, compressed exponents}` (paper §4.1/§4.3).
//! * [`prng`], [`proptest`] — deterministic PRNG + a minimal property-test
//!   driver (the offline crate set has no `rand`/`proptest`; these are
//!   first-class substrates here, not mocks).
//! * [`batch`] — §Perf: the word-at-a-time batch codec engine (pair-fused
//!   encode, refill-based block decode, N-lane interleaved streams) that
//!   the scalar codecs above are the bit-exact oracle for.
//! * [`integrity`] — CRC-16 (CCITT-FALSE) stream integrity for the
//!   `LaneStream` v3 wire format and sealed [`codec::CodedBlock`]s
//!   (ISSUE 6): corrupted payloads surface as
//!   [`Error::Corrupt`](error::Error::Corrupt), never as wrong symbols.
//! * [`lut`] — §Perf: the multi-symbol decode LUT
//!   ([`MultiDecodeTable`](lut::MultiDecodeTable)): one direct-table
//!   probe emits up to 4 exponents, with sentinel fallback to the
//!   canonical kernel so output stays bit-identical.
//! * [`swar`] — §Perf (ISSUE 8): SWAR primitives (packed byte-compare
//!   refill gate, grouped table gather; optional AVX2 arm behind the
//!   off-by-default `simd` feature) for the grouped lockstep decoder.
//! * [`pool`] — §Perf (ISSUE 8): dependency-free sharded thread pool
//!   (scoped spawn-per-shard, no work stealing) behind
//!   [`huffman::compress_exponents_par`] /
//!   [`huffman::decompress_exponents_par`] and the lane-parallel
//!   [`batch::LaneCodec`] paths; results are deterministic and
//!   thread-count invariant.
//!
//! The cycle-accurate hardware realization lives in `lexi-hw`; this crate is
//! the bit-exact oracle it is tested against.

pub mod batch;
pub mod bdi;
pub mod bf16;
pub mod bitstream;
pub mod codec;
pub mod error;
pub mod flit;
pub mod huffman;
pub mod integrity;
pub mod lut;
pub mod pool;
pub mod prng;
pub mod proptest;
pub mod rle;
pub mod stats;
pub mod swar;

pub use bf16::Bf16;
pub use error::{Error, Result};
