//! §Perf — SWAR primitives for the grouped lockstep decoder (ISSUE 8).
//!
//! The lockstep lane loop in [`batch`] keeps one small counter per lane
//! (`navail`, the valid-bit count of the lane's refill window, always
//! ≤ 64). Deciding which of a group of [`GROUP`] lanes need a refill is
//! a byte-wise unsigned compare — exactly the shape SWAR (SIMD Within A
//! Register) handles in three ALU ops on a packed `u64`:
//!
//! ```text
//! below(x, n) = !((x | 0x8080…80) - n·0x0101…01) & 0x8080…80
//! ```
//!
//! The trick sets bit 7 of every byte whose value is `< n`. Pre-setting
//! each byte's MSB makes every per-byte difference non-negative
//! (`b + 128 - n ≥ 0` for `b ≥ 0`, `n ≤ 128`), so **no borrow ever
//! crosses a byte boundary** and the compare is *exact* per byte
//! whenever every packed byte and the threshold are `< 128`. (The
//! textbook `(x - n·LSB) & !x & MSB` form is only an any-byte-below
//! detector: a borrow out of a flagged byte falsely flags a neighbour
//! equal to `n`.) Both operands here are far inside the valid range
//! (`navail ≤ 64`, cadence threshold 40), and exactness is pinned
//! exhaustively below and mirrored in `tools/logic_check.py` §[14].
//!
//! The second primitive is a grouped **gather**: the per-lane
//! [`MultiDecodeTable`] probes of a lockstep pass have no data
//! dependence on each other, so issuing all [`GROUP`] table loads before
//! consuming any result lets them pipeline (software pipelining on every
//! target). Behind the off-by-default `simd` feature the shared-table
//! path upgrades to a real AVX2 `vpgatherqq` when the CPU has it; the
//! SWAR/scalar path is the always-on fallback and the bit-exactness
//! oracle.
//!
//! [`batch`]: crate::batch
//! [`MultiDecodeTable`]: crate::lut::MultiDecodeTable

/// Lanes advanced per grouped lockstep step: 8 byte-counters fill one
/// `u64` exactly, and 8 matches the paper's decoder-sweep lane count.
pub const GROUP: usize = 8;

/// Per-byte LSB mask (the SWAR "1" broadcast).
const LSB: u64 = 0x0101_0101_0101_0101;

/// Per-byte MSB mask (the SWAR compare-result bit).
const MSB: u64 = 0x8080_8080_8080_8080;

/// Pack up to [`GROUP`] small counters into one `u64`, value `i` into
/// byte `i`. Callers must keep every value `< 128` for the packed
/// compare to be exact (`navail ≤ 64` always is); debug-asserted here.
#[inline]
pub fn pack_bytes(vals: &[u32]) -> u64 {
    debug_assert!(vals.len() <= GROUP);
    let mut packed = 0u64;
    for (i, &v) in vals.iter().enumerate() {
        debug_assert!(v < 128, "packed byte {v} would corrupt the SWAR compare");
        packed |= (v as u64) << (8 * i);
    }
    packed
}

/// Byte-wise unsigned `< n` over a packed `u64`: bit 7 of byte `i` is
/// set iff byte `i` of `packed` is below `n`. Exact for bytes and
/// threshold `< 128`: the `| MSB` keeps every per-byte difference
/// non-negative, so borrows never cross byte boundaries (module docs).
#[inline]
pub fn bytes_below(packed: u64, n: u8) -> u64 {
    debug_assert!(n < 128);
    !((packed | MSB).wrapping_sub((n as u64) * LSB)) & MSB
}

/// Restrict a [`bytes_below`] mask to the low `g` bytes — groups at the
/// tail of an odd lane count pack fewer than [`GROUP`] counters, and the
/// zero bytes above them would otherwise read as "below threshold".
#[inline]
pub fn group_mask(g: usize) -> u64 {
    debug_assert!(g >= 1 && g <= GROUP);
    if g == GROUP {
        !0
    } else {
        (1u64 << (8 * g)) - 1
    }
}

/// Iterator over the flagged byte indices of a [`bytes_below`]-style
/// mask, lowest lane first.
#[derive(Clone, Copy, Debug)]
pub struct FlaggedLanes(pub u64);

impl Iterator for FlaggedLanes {
    type Item = usize;

    #[inline]
    fn next(&mut self) -> Option<usize> {
        if self.0 == 0 {
            return None;
        }
        let lane = (self.0.trailing_zeros() / 8) as usize;
        // Clear the lowest set bit (each byte carries exactly one).
        self.0 &= self.0 - 1;
        Some(lane)
    }
}

/// Grouped table gather: load `entries[idx[j]]` for `j < g` into
/// `out[..g]`, issuing every load before any result is consumed — the
/// scalar form of a vector gather, which is all the portable path needs
/// for the loads to pipeline. With the `simd` feature on an AVX2 x86-64
/// this becomes a real `vpgatherqq` pair (runtime-detected; the scalar
/// loop remains the fallback and the bit-exactness oracle).
#[inline]
pub fn gather(entries: &[u64], idx: &[usize; GROUP], g: usize, out: &mut [u64; GROUP]) {
    debug_assert!(g >= 1 && g <= GROUP);
    debug_assert!(idx[..g].iter().all(|&i| i < entries.len()));
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if g == GROUP && avx2::available() {
            // SAFETY: indices bounds-checked above; AVX2 presence checked.
            unsafe { avx2::gather8(entries, idx, out) };
            return;
        }
    }
    for j in 0..g {
        out[j] = entries[idx[j]];
    }
}

/// AVX2 gather arm — compiled only under the off-by-default `simd`
/// feature so the default build carries zero `unsafe` and zero
/// target-specific code; dispatched at runtime via
/// `is_x86_feature_detected!`, cached in a `OnceLock`.
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod avx2 {
    use super::GROUP;
    use std::sync::OnceLock;

    pub(super) fn available() -> bool {
        static AVX2: OnceLock<bool> = OnceLock::new();
        *AVX2.get_or_init(|| std::arch::is_x86_feature_detected!("avx2"))
    }

    /// # Safety
    /// Caller guarantees AVX2 is available and `idx[j] < entries.len()`
    /// for all `j < GROUP`.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn gather8(entries: &[u64], idx: &[usize; GROUP], out: &mut [u64; GROUP]) {
        use std::arch::x86_64::*;
        let base = entries.as_ptr() as *const i64;
        let lo = _mm256_set_epi64x(idx[3] as i64, idx[2] as i64, idx[1] as i64, idx[0] as i64);
        let hi = _mm256_set_epi64x(idx[7] as i64, idx[6] as i64, idx[5] as i64, idx[4] as i64);
        let a = _mm256_i64gather_epi64::<8>(base, lo);
        let b = _mm256_i64gather_epi64::<8>(base, hi);
        _mm256_storeu_si256(out.as_mut_ptr() as *mut __m256i, a);
        _mm256_storeu_si256(out.as_mut_ptr().add(4) as *mut __m256i, b);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest::check;

    #[test]
    fn bytes_below_is_exact_for_all_navail_values() {
        // Exhaustive over the actual domain: every byte value a lane's
        // `navail` can take (0..=64) against every cadence threshold the
        // decoders use (1..128). One packed word per (value, position).
        for n in 1..128u8 {
            for v in 0..=64u32 {
                for pos in 0..GROUP {
                    let mut vals = [7u32; GROUP];
                    vals[pos] = v;
                    let packed = pack_bytes(&vals);
                    let mask = bytes_below(packed, n);
                    for (i, &vi) in vals.iter().enumerate() {
                        let flagged = mask & (0x80 << (8 * i)) != 0;
                        assert_eq!(
                            flagged,
                            vi < n as u32,
                            "n={n} byte {i}={vi}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn prop_bytes_below_matches_per_byte_compare() {
        check("swar bytes_below == per-byte <", 300, |g| {
            let len = g.usize(1..GROUP + 1);
            let vals: Vec<u32> = (0..len).map(|_| g.usize(0..128) as u32).collect();
            let n = g.usize(1..128) as u8;
            let mask = bytes_below(pack_bytes(&vals), n) & group_mask(len);
            let want: Vec<usize> = vals
                .iter()
                .enumerate()
                .filter(|&(_, &v)| v < n as u32)
                .map(|(i, _)| i)
                .collect();
            let got: Vec<usize> = FlaggedLanes(mask).collect();
            assert_eq!(got, want, "vals {vals:?} n {n}");
        });
    }

    #[test]
    fn group_mask_covers_exactly_g_bytes() {
        for g in 1..=GROUP {
            let m = group_mask(g);
            for byte in 0..GROUP {
                let covered = m & (0xff << (8 * byte)) != 0;
                assert_eq!(covered, byte < g, "g={g} byte {byte}");
            }
        }
    }

    #[test]
    fn prop_gather_matches_indexing() {
        check("gather == entries[idx]", 200, |g| {
            let len = g.usize(1..5000);
            let entries: Vec<u64> = (0..len).map(|_| g.u64(0..u64::MAX)).collect();
            let mut idx = [0usize; GROUP];
            for slot in idx.iter_mut() {
                *slot = g.usize(0..len);
            }
            let n = g.usize(1..GROUP + 1);
            let mut out = [0u64; GROUP];
            gather(&entries, &idx, n, &mut out);
            for j in 0..n {
                assert_eq!(out[j], entries[idx[j]], "slot {j}");
            }
        });
    }
}
