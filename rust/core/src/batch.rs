//! §Perf — the batch codec engine: word-at-a-time encode and multi-lane
//! interleaved streams.
//!
//! The scalar paths in [`huffman`] are the bit-exact oracle; this module
//! is how the software hot loop actually runs them (DESIGN.md §Perf):
//!
//! * [`BatchEncoder`] — a pair-fused table encoder. The ≤32-symbol LEXI
//!   alphabet (paper §4.2.2) makes a dense `nsym × nsym` pair LUT tiny
//!   (≤ 16 KiB), so two exponents cost one lookup + one [`BitWriter::put`]
//!   whenever their combined codeword fits the 64-bit accumulator.
//!   Escaped symbols fall back to the packed scalar LUT.
//! * [`LaneCodec`] / [`LaneStream`] — an `N`-lane interleaved stream
//!   format mirroring the paper's multi-lane LUT decoder (§4.4): symbol
//!   `i` goes to lane `i mod N` and each lane is an independent bitstream,
//!   so `N` refill decoders proceed without serial bit-offset dependencies
//!   (physical lanes in hardware, instruction-level parallelism in
//!   software). Lanes share one codebook by default; the v2 header
//!   ([`LANE_BOOKS_FLAG`]) optionally embeds **per-lane codebooks** for
//!   multi-tenant links whose lanes carry differently-distributed streams.
//! * [`LaneCodec::decode_lockstep`] — the lockstep interleaved decoder
//!   (§Perf, DESIGN.md §Lockstep): all `N` windows held live in
//!   struct-of-arrays state ([`LaneWindows`]) and advanced round-robin,
//!   so the `N` independent table lookups pipeline instead of running
//!   lane-at-a-time; on long streams each visit drains up to
//!   [`lut::LUT_MAX_SYMS`] symbols in one multi-symbol LUT probe
//!   (ISSUE 4, DESIGN.md §Multi-symbol LUT).
//!
//! The refill-based block *decoder* lives on
//! [`CanonicalDecoder::decode_block_into`], next to the tables it probes.
//!
//! [`huffman`]: crate::huffman
//! [`CanonicalDecoder::decode_block_into`]: crate::huffman::CanonicalDecoder::decode_block_into
//! [`LaneWindows`]: crate::bitstream::LaneWindows

use crate::bitstream::{BitReader, BitWriter, LaneWindows};
use crate::error::{Error, Result};
use crate::huffman::{CanonicalDecoder, CodeBook, ESC_SYMBOL};
use crate::integrity::crc16;
use crate::lut::{self, MultiDecodeTable};
use crate::pool;
use crate::swar;

/// Maximum supported lane count (8 matches the paper's decoder sweep;
/// headroom beyond it costs nothing in the format). Must stay ≤ 127 so
/// the lane count shares the header byte with [`LANE_BOOKS_FLAG`].
pub const MAX_LANES: usize = 64;

/// v2 header flag (top bit of the first wire byte): the stream embeds
/// one codebook per lane. v1 streams have the bit clear, so every v1
/// byte sequence parses identically under the v2 reader.
pub const LANE_BOOKS_FLAG: u8 = 0x80;

/// v3 escape byte (ISSUE 6): a first wire byte of `0x00` — an *invalid*
/// lane count under v1/v2, rejected by every earlier reader — announces
/// the checksummed v3 layout. The real flags/lanes byte follows at
/// offset 1, so v1/v2 streams keep parsing byte-identically and v3
/// streams fed to an old reader fail loudly instead of misdecoding.
pub const LANE_CRC_ESCAPE: u8 = 0x00;

/// Largest serialized per-lane codebook header we accept, in bits: the
/// `count:6` field of [`CodeBook::write_header`] caps entries at 63, at
/// 14 bits each plus the 6-bit count. A hostile header demanding more is
/// rejected before any book parsing or allocation.
pub const MAX_BOOK_HEADER_BITS: u32 = 6 + 14 * 63;

/// Pair LUT is built only for alphabets up to this size: the paper's
/// pipeline caps the primary alphabet at 32, and a degenerate 256-symbol
/// book would need a 1 MiB table that no longer fits in L1/L2.
const PAIR_MAX_SYMS: usize = 64;

/// Sentinel in the dense-index table for "no dedicated code".
const NO_PAIR: u8 = 0xff;

/// Word-at-a-time encoder over one codebook (§Perf).
///
/// Construction cost is `O(nsym²)` table fills (≤ 4096 entries), so build
/// it once per stream/transfer, not per flit.
pub struct BatchEncoder<'a> {
    book: &'a CodeBook,
    /// Dense pair-LUT index per exponent, or [`NO_PAIR`].
    dense: [u8; 256],
    /// Dedicated-symbol count = pair-LUT stride.
    nsym: usize,
    /// Fused `(bits, len)` per dense symbol pair; `len == 0` marks a pair
    /// whose combined code exceeds one `put` (fall back to two).
    pair: Vec<(u64, u32)>,
}

impl<'a> BatchEncoder<'a> {
    /// Build the pair-fused encoder for `book`.
    pub fn new(book: &'a CodeBook) -> Self {
        let mut dense = [NO_PAIR; 256];
        let mut dedicated: Vec<u8> = Vec::new();
        for &(sym, _) in book.canonical_pairs() {
            if sym != ESC_SYMBOL && dedicated.len() < PAIR_MAX_SYMS {
                dense[sym as usize] = dedicated.len() as u8;
                dedicated.push(sym as u8);
            }
        }
        let nsym = dedicated.len();
        let mut pair = Vec::new();
        if nsym > 0 {
            pair = vec![(0u64, 0u32); nsym * nsym];
            for (i, &a) in dedicated.iter().enumerate() {
                let ca = book.code(a).expect("dedicated symbol has a code");
                for (j, &b) in dedicated.iter().enumerate() {
                    let cb = book.code(b).expect("dedicated symbol has a code");
                    let len = ca.len + cb.len;
                    // One `put` takes ≤ 56 bits; dedicated codes are ≤ 31
                    // each, so only pathological books exceed this.
                    if len <= 56 {
                        pair[i * nsym + j] =
                            (((ca.bits as u64) << cb.len) | cb.bits as u64, len);
                    }
                }
            }
        }
        BatchEncoder {
            book,
            dense,
            nsym,
            pair,
        }
    }

    /// Fused `(bits, len)` for the dedicated pair `(a, b)`, if fusable.
    #[inline]
    fn pair_of(&self, a: u8, b: u8) -> Option<(u64, u32)> {
        let (da, db) = (self.dense[a as usize], self.dense[b as usize]);
        if da != NO_PAIR && db != NO_PAIR {
            let entry = self.pair[da as usize * self.nsym + db as usize];
            if entry.1 != 0 {
                return Some(entry);
            }
        }
        None
    }

    /// Emit a two-symbol group: one fused put, or two scalar LUT puts.
    #[inline]
    fn emit_duo(&self, a: u8, b: u8, fused: Option<(u64, u32)>, w: &mut BitWriter) {
        match fused {
            Some((bits, len)) => w.put(bits, len),
            None => {
                self.book.encode_symbol(a, w);
                self.book.encode_symbol(b, w);
            }
        }
    }

    /// Encode `exps` into `w`: up to **four symbols per `put`** — two
    /// pair-LUT lookups fused into one accumulator write when the combined
    /// length fits 56 bits (always, for realistic ≤ 7-bit/pair books).
    /// Emits exactly the bits the scalar [`CodeBook::encode_symbol`] loop
    /// would: fusing is MSB-first concatenation, which is associative.
    pub fn encode_block(&self, exps: &[u8], w: &mut BitWriter) {
        if self.pair.is_empty() {
            for &e in exps {
                self.book.encode_symbol(e, w);
            }
            return;
        }
        let mut quads = exps.chunks_exact(4);
        for quad in quads.by_ref() {
            let lo = self.pair_of(quad[0], quad[1]);
            let hi = self.pair_of(quad[2], quad[3]);
            match (lo, hi) {
                (Some((b1, l1)), Some((b2, l2))) if l1 + l2 <= 56 => {
                    w.put((b1 << l2) | b2, l1 + l2);
                }
                (lo, hi) => {
                    self.emit_duo(quad[0], quad[1], lo, w);
                    self.emit_duo(quad[2], quad[3], hi, w);
                }
            }
        }
        let mut duos = quads.remainder().chunks_exact(2);
        for duo in duos.by_ref() {
            let fused = self.pair_of(duo[0], duo[1]);
            self.emit_duo(duo[0], duo[1], fused, w);
        }
        if let &[last] = duos.remainder() {
            self.book.encode_symbol(last, w);
        }
    }
}

/// `N`-lane interleaved stream codec (paper §4.4, software mirror).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LaneCodec {
    lanes: usize,
    /// Emit the checksummed v3 wire format (ISSUE 6): per-lane CRC-16
    /// plus a header CRC. Off by default so every pre-v3 byte pin holds.
    checksummed: bool,
}

impl LaneCodec {
    /// A codec with `lanes` ∈ `1..=MAX_LANES`.
    pub fn new(lanes: usize) -> Result<Self> {
        if lanes == 0 || lanes > MAX_LANES {
            return Err(Error::InvalidParameter(format!(
                "lane count {lanes} out of range 1..={MAX_LANES}"
            )));
        }
        Ok(LaneCodec {
            lanes,
            checksummed: false,
        })
    }

    /// Builder: emit the v3 checksummed wire format. Decoding needs no
    /// opt-in — [`LaneStream::from_bytes`] recognizes the escape byte
    /// and [`LaneStream::validated_lanes`] verifies whatever CRCs the
    /// stream carries.
    pub fn with_checksums(mut self) -> Self {
        self.checksummed = true;
        self
    }

    /// Whether encodes emit the checksummed v3 format.
    pub fn checksummed(&self) -> bool {
        self.checksummed
    }

    /// Lane count.
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Encode `exps` round-robin across the lanes (symbol `i` → lane
    /// `i mod N`), each lane through the pair-fused batch encoder over
    /// one shared codebook (v1 wire format).
    pub fn encode(&self, exps: &[u8], book: &CodeBook) -> LaneStream {
        let enc = BatchEncoder::new(book);
        let encs: Vec<&BatchEncoder> = vec![&enc; self.lanes];
        self.encode_with(exps, &encs, None)
    }

    /// Encode with one codebook **per lane** (v2 wire format): lane `l`'s
    /// substream is encoded with `books[l]`, and all `lanes` book headers
    /// ride in the stream so the receiver needs no side channel. This is
    /// the multi-tenant link shape: differently-distributed streams share
    /// the physical lanes, each under its own code.
    ///
    /// Errors if `books.len() != lanes` or a book is too large to
    /// serialize (more than 63 canonical entries — see
    /// [`CodeBook::write_header`]'s 6-bit count field).
    pub fn encode_per_lane(&self, exps: &[u8], books: &[CodeBook]) -> Result<LaneStream> {
        if books.len() != self.lanes {
            return Err(Error::InvalidParameter(format!(
                "{} books for {} lanes",
                books.len(),
                self.lanes
            )));
        }
        for (l, b) in books.iter().enumerate() {
            if b.canonical_pairs().len() > 63 {
                return Err(Error::InvalidParameter(format!(
                    "lane {l}: codebook with {} entries exceeds the 63-entry wire header",
                    b.canonical_pairs().len()
                )));
            }
        }
        let encs_owned: Vec<BatchEncoder> = books.iter().map(BatchEncoder::new).collect();
        let encs: Vec<&BatchEncoder> = encs_owned.iter().collect();
        Ok(self.encode_with(exps, &encs, Some(books)))
    }

    /// Shared encode core: round-robin split, per-lane batch encode, then
    /// header + optional book table + payload serialization. The per-lane
    /// payload unit is [`lane_payload`], shared with [`encode_par`] so
    /// the sequential and sharded paths stay byte-identical by
    /// construction.
    ///
    /// [`encode_par`]: LaneCodec::encode_par
    fn encode_with(
        &self,
        exps: &[u8],
        encs: &[&BatchEncoder],
        books: Option<&[CodeBook]>,
    ) -> LaneStream {
        let n = self.lanes;
        debug_assert_eq!(encs.len(), n);
        // Release-safe guards: the wire header stores count and per-lane
        // bit lengths as u32; silent wrapping would serialize a stream
        // that decodes to the wrong symbols.
        assert!(
            exps.len() <= u32::MAX as usize,
            "lane stream supports at most u32::MAX symbols"
        );
        let mut payloads: Vec<Vec<u8>> = Vec::with_capacity(n);
        let mut lane_bits: Vec<u32> = Vec::with_capacity(n);
        for l in 0..n {
            let (payload, bits) = lane_payload(exps, n, l, encs[l]);
            lane_bits.push(bits);
            payloads.push(payload);
        }
        self.assemble(exps.len(), payloads, lane_bits, books)
    }

    /// Serialize computed lane payloads into the wire format (header +
    /// optional book table + v3 trailer + payloads). Single-threaded and
    /// order-fixed, so every encode path producing identical payloads
    /// produces identical bytes.
    fn assemble(
        &self,
        count: usize,
        payloads: Vec<Vec<u8>>,
        lane_bits: Vec<u32>,
        books: Option<&[CodeBook]>,
    ) -> LaneStream {
        let n = self.lanes;
        // Serialized per-lane book headers (v2 only).
        let mut book_bits: Vec<u16> = Vec::new();
        let mut book_blobs: Vec<Vec<u8>> = Vec::new();
        if let Some(bs) = books {
            for b in bs {
                let mut w = BitWriter::new();
                b.write_header(&mut w);
                debug_assert!(w.len_bits() as u32 <= MAX_BOOK_HEADER_BITS);
                book_bits.push(w.len_bits() as u16);
                book_blobs.push(w.into_bytes());
            }
        }

        let payload_len: usize = payloads.iter().map(Vec::len).sum();
        let books_len: usize =
            book_blobs.iter().map(Vec::len).sum::<usize>() + 2 * book_bits.len();
        let crc_len = if self.checksummed { 1 + 2 * n + 2 } else { 0 };
        let mut bytes = Vec::with_capacity(5 + 4 * n + books_len + crc_len + payload_len);
        if self.checksummed {
            bytes.push(LANE_CRC_ESCAPE);
        }
        bytes.push(n as u8 | if books.is_some() { LANE_BOOKS_FLAG } else { 0 });
        bytes.extend_from_slice(&(count as u32).to_be_bytes());
        for &b in &lane_bits {
            bytes.extend_from_slice(&b.to_be_bytes());
        }
        for &b in &book_bits {
            bytes.extend_from_slice(&b.to_be_bytes());
        }
        for blob in &book_blobs {
            bytes.extend_from_slice(blob);
        }
        // v3 trailer of the header (ISSUE 6): per-lane payload CRCs,
        // then a CRC over every header byte emitted so far *including*
        // the lane-CRC table — so a flipped header bit (count, lane
        // length, book table, or a lane CRC itself) is detected before
        // any payload range is trusted.
        let mut lane_crc: Vec<u16> = Vec::new();
        if self.checksummed {
            lane_crc = payloads.iter().map(|p| crc16(p)).collect();
            for &c in &lane_crc {
                bytes.extend_from_slice(&c.to_be_bytes());
            }
            let header_crc = crc16(&bytes);
            bytes.extend_from_slice(&header_crc.to_be_bytes());
        }
        for p in &payloads {
            bytes.extend_from_slice(p);
        }
        LaneStream {
            lanes: n,
            count,
            lane_bits,
            book_bits,
            books: books.map(|b| b.to_vec()).unwrap_or_default(),
            lane_crc,
            bytes,
        }
    }

    /// Decode a lane stream back to the original symbol order, one lane
    /// at a time (each through the refill block decoder). Inverse of
    /// [`encode`] / [`encode_per_lane`]; embedded per-lane books take
    /// precedence over the `book` argument.
    ///
    /// This is the measurement baseline for [`decode_lockstep`], which is
    /// the faster path — lane-at-a-time drains each lane's dependence
    /// chain serially.
    ///
    /// [`encode`]: LaneCodec::encode
    /// [`encode_per_lane`]: LaneCodec::encode_per_lane
    /// [`decode_lockstep`]: LaneCodec::decode_lockstep
    pub fn decode(stream: &LaneStream, book: &CodeBook) -> Result<Vec<u8>> {
        // Validation first: `count` is only trusted (and allocated) after
        // `validated_lanes` has bounded it by the payload bit lengths.
        let views = stream.validated_lanes()?;
        let n = stream.lanes;
        let decs = LaneDecoders::for_stream(stream, book);
        let mut out = vec![0u8; stream.count];
        let mut tmp = vec![0u8; stream.count.div_ceil(n)];
        for v in views {
            let dec = decs.lane(v.lane);
            let mut r = BitReader::with_len(&stream.bytes[v.range.clone()], v.bits as usize);
            let lane_out = &mut tmp[..v.symbols];
            dec.decode_block_into(&mut r, lane_out)?;
            for (k, &sym) in lane_out.iter().enumerate() {
                out[v.lane + k * n] = sym;
            }
        }
        Ok(out)
    }

    /// Decode a lane stream with **all lanes held live in one lockstep
    /// round-robin loop** (§Perf, DESIGN.md §Lockstep) — the software
    /// analogue of the paper's N parallel LUT decoders sustaining link
    /// bandwidth (§4.4).
    ///
    /// State is struct-of-arrays ([`LaneWindows`]): per-lane window,
    /// bit-position and refill cursor in parallel arrays. Each
    /// round-robin visit decodes from one lane — the N window probes
    /// have no data dependence on each other (they pipeline in the CPU)
    /// — and on streams past [`lut::LUT_DECODE_MIN_SYMBOLS`] a visit
    /// drains **up to [`lut::LUT_MAX_SYMS`] symbols in one multi-LUT
    /// probe** (ISSUE 4), multiplying the lockstep win; short streams
    /// and [`decode_lockstep_scalar`] keep the one-symbol-per-visit
    /// kernel.
    ///
    /// Bit-exact with [`decode`] and with the scalar per-symbol oracle:
    /// each lane consumes exactly the bits the lane-at-a-time path does
    /// (pinned by property tests). Embedded per-lane books take
    /// precedence over the `book` argument.
    ///
    /// [`decode`]: LaneCodec::decode
    /// [`LaneWindows`]: crate::bitstream::LaneWindows
    pub fn decode_lockstep(stream: &LaneStream, book: &CodeBook) -> Result<Vec<u8>> {
        // §Perf (ISSUE 4): streams long enough to amortize the table
        // fills drain up to LUT_MAX_SYMS symbols per lane visit. A
        // shared book needs one fill; embedded per-lane books need one
        // *per lane*, so the threshold applies to each table's share of
        // the symbols, not the total.
        let fills = stream.books.len().max(1);
        let decs = if lut::amortizes_fill(stream.count / fills) {
            LaneDecoders::for_stream_lut(stream, book)
        } else {
            LaneDecoders::for_stream(stream, book)
        };
        Self::decode_lockstep_swar(stream, &decs)
    }

    /// [`decode_lockstep`] pinned to scalar (one-symbol-per-visit)
    /// decoders regardless of stream size — the measurement baseline the
    /// `decode lockstep={4,8}` bench rows track, and the ISSUE 2 shape
    /// the multi-symbol LUT path is compared against.
    ///
    /// [`decode_lockstep`]: LaneCodec::decode_lockstep
    pub fn decode_lockstep_scalar(stream: &LaneStream, book: &CodeBook) -> Result<Vec<u8>> {
        Self::decode_lockstep_with(stream, &LaneDecoders::for_stream(stream, book))
    }

    /// Lockstep core over caller-built decoder tables. Each round-robin
    /// visit to a lane drains **up to [`lut::LUT_MAX_SYMS`] symbols in
    /// one multi-LUT probe** when the lane's decoder carries a table
    /// (else exactly one via the scalar kernel): lane `l`'s `k`-th
    /// symbol lands at `out[l + k*n]`, so the multi drain is a short
    /// strided scatter — 1 probe per ~3–4 symbols buys back far more
    /// than the scatter costs on < 3-bit-entropy streams. Per-lane bit
    /// consumption, decoded symbols, and each lane's *own* failure
    /// point are identical to the scalar loop (the LUT only fires on
    /// full-fit entries); the one divergence: lanes progress at
    /// different rates under the multi drain, so when **several** lanes
    /// are malformed, *which* lane's error surfaces first may differ
    /// from the one-symbol-per-round order. Both paths always error on
    /// a stream either would reject.
    pub fn decode_lockstep_with(stream: &LaneStream, decs: &LaneDecoders) -> Result<Vec<u8>> {
        let views = stream.validated_lanes()?;
        let n = stream.lanes;
        // Per-lane decoder table, hoisting the shared-vs-per-lane branch
        // out of the hot loop.
        let dec_by_lane = decs.by_lane(n);
        let mut out = vec![0u8; stream.count];
        let spans: Vec<(usize, usize)> = views
            .iter()
            .map(|v| (v.range.start * 8, v.range.start * 8 + v.bits as usize))
            .collect();
        let mut wins = LaneWindows::new(&stream.bytes, &spans);
        // Round-robin visits until every lane has produced its share;
        // unfinished lanes are visited once per pass (with scalar
        // decoders this is exactly the one-symbol-per-round loop; with
        // multi drains, lanes advance at different rates — see the doc
        // caveat on multi-lane error ordering). The refill cadence
        // matches decode_block_into: ≥ 40 valid bits per visit (worst
        // codeword + escape byte ≤ 39 bits; a LUT probe consumes ≤
        // LUT_BITS).
        let lane_syms: Vec<usize> = views.iter().map(|v| v.symbols).collect();
        let mut done = vec![0usize; n];
        let mut live = true;
        while live {
            live = false;
            for l in 0..n {
                let want = lane_syms[l] - done[l];
                if want == 0 {
                    continue;
                }
                live = true;
                wins.ensure(l, 40);
                if let Some(table) = dec_by_lane[l].multi_table() {
                    let e = table.entry(wins.window(l));
                    let c = MultiDecodeTable::count(e) as usize;
                    let used = MultiDecodeTable::consumed(e);
                    if c != 0 && c <= want && used as usize <= wins.remaining(l) {
                        for (k, &sym) in e.to_le_bytes()[..c].iter().enumerate() {
                            out[l + (done[l] + k) * n] = sym;
                        }
                        wins.consume(l, used);
                        done[l] += c;
                        continue;
                    }
                }
                let (sym, used) = dec_by_lane[l].decode_from_window(
                    wins.window(l),
                    wins.remaining(l),
                    wins.pos(l),
                )?;
                out[l + done[l] * n] = sym;
                wins.consume(l, used);
                done[l] += 1;
            }
        }
        Ok(out)
    }

    /// The grouped SWAR lockstep loop (ISSUE 8 tentpole) — what
    /// [`decode_lockstep`] actually runs. Advances up to
    /// [`swar::GROUP`] lanes per step in three phases:
    ///
    /// 1. **SWAR refill gate**: one packed byte-compare over the group's
    ///    `navail` counters ([`LaneWindows::ensure_group`]) flags every
    ///    lane below the 40-bit cadence; only those refill.
    /// 2. **Grouped probes**: all the group's [`MultiDecodeTable`] loads
    ///    are issued before any result is consumed ([`swar::gather`] on
    ///    the shared-table path — a real AVX2 `vpgatherqq` under the
    ///    `simd` feature), so the per-lane loads pipeline instead of
    ///    alternating with the scatter/consume.
    /// 3. **Apply in lane order**: each active lane drains its probe
    ///    entry (or the scalar kernel on the `count = 0` sentinel, also
    ///    covering decoders with no table at all), identical to one
    ///    [`decode_lockstep_with`] visit.
    ///
    /// Bit-identical to [`decode_lockstep_with`] over the same `decs` —
    /// outputs *and* typed error details (property-pinned below,
    /// mirrored in `tools/logic_check.py` §[14]): lanes are
    /// state-independent, so batching the probes of a pass cannot change
    /// any lane's bit consumption, and applying in lane order preserves
    /// the reference loop's round-major error ordering. Refilling an
    /// already-finished lane's window (phase 1 gates on `navail`, not on
    /// `want`) only loads bytes that are never consumed.
    ///
    /// [`decode_lockstep`]: LaneCodec::decode_lockstep
    /// [`decode_lockstep_with`]: LaneCodec::decode_lockstep_with
    /// [`LaneWindows::ensure_group`]: crate::bitstream::LaneWindows::ensure_group
    pub fn decode_lockstep_swar(stream: &LaneStream, decs: &LaneDecoders) -> Result<Vec<u8>> {
        let views = stream.validated_lanes()?;
        let n = stream.lanes;
        let dec_by_lane = decs.by_lane(n);
        // Hoisted table pointers: per-lane Option, plus the raw entry
        // slice when one shared table serves every lane (gather path).
        let tables: Vec<Option<&MultiDecodeTable>> =
            dec_by_lane.iter().map(|d| d.multi_table()).collect();
        let shared_entries: Option<&[u64]> = decs
            .shared()
            .and_then(|d| d.multi_table())
            .map(|t| t.entries());
        let mut out = vec![0u8; stream.count];
        let spans: Vec<(usize, usize)> = views
            .iter()
            .map(|v| (v.range.start * 8, v.range.start * 8 + v.bits as usize))
            .collect();
        let mut wins = LaneWindows::new(&stream.bytes, &spans);
        let lane_syms: Vec<usize> = views.iter().map(|v| v.symbols).collect();
        let mut done = vec![0usize; n];
        let mut probes = [0u64; swar::GROUP];
        let mut idx = [0usize; swar::GROUP];
        let mut live = true;
        while live {
            live = false;
            let mut l0 = 0;
            while l0 < n {
                let g = (n - l0).min(swar::GROUP);
                // Phase 1: grouped refill gate (40-bit cadence: worst
                // codeword + escape byte ≤ 39 bits, LUT probe ≤ LUT_BITS).
                wins.ensure_group(l0, g, 40);
                // Phase 2: issue every probe before consuming any. A zero
                // entry is the `count = 0` sentinel, so lanes without a
                // table fall through to the scalar kernel in phase 3.
                if let Some(entries) = shared_entries {
                    for j in 0..g {
                        idx[j] = (wins.window(l0 + j) >> (64 - lut::LUT_BITS)) as usize;
                    }
                    swar::gather(entries, &idx, g, &mut probes);
                } else {
                    for j in 0..g {
                        probes[j] = match tables[l0 + j] {
                            Some(t) => t.entry(wins.window(l0 + j)),
                            None => 0,
                        };
                    }
                }
                // Phase 3: apply in lane order — one reference visit per
                // active lane, error ordering preserved.
                for j in 0..g {
                    let l = l0 + j;
                    let want = lane_syms[l] - done[l];
                    if want == 0 {
                        continue;
                    }
                    live = true;
                    let e = probes[j];
                    let c = MultiDecodeTable::count(e) as usize;
                    let used = MultiDecodeTable::consumed(e);
                    if c != 0 && c <= want && used as usize <= wins.remaining(l) {
                        for (k, &sym) in e.to_le_bytes()[..c].iter().enumerate() {
                            out[l + (done[l] + k) * n] = sym;
                        }
                        wins.consume(l, used);
                        done[l] += c;
                        continue;
                    }
                    let (sym, used) = dec_by_lane[l].decode_from_window(
                        wins.window(l),
                        wins.remaining(l),
                        wins.pos(l),
                    )?;
                    out[l + done[l] * n] = sym;
                    wins.consume(l, used);
                    done[l] += 1;
                }
                l0 += g;
            }
        }
        Ok(out)
    }

    /// Lane-parallel decode (ISSUE 8): each lane's independent bitstream
    /// decodes on its own shard of the [`pool`] (block decoder +
    /// the same [`lut::amortizes_fill`] table policy as
    /// [`decode_lockstep`]), then symbols scatter back to round-robin
    /// order on the caller's thread. Deterministic and thread-count
    /// invariant: shard → thread assignment is static, outputs are
    /// recombined in lane order, and the surfaced error is the **first
    /// failing lane in lane index order** — exactly [`decode`]'s error
    /// (property-pinned below). This is a wall-clock path for big
    /// streams; the simulator's cycle model keeps measuring the
    /// single-thread paths (DESIGN.md §SIMD & sharded parallelism).
    ///
    /// [`decode`]: LaneCodec::decode
    /// [`decode_lockstep`]: LaneCodec::decode_lockstep
    /// [`pool`]: crate::pool
    pub fn decode_par(stream: &LaneStream, book: &CodeBook, threads: usize) -> Result<Vec<u8>> {
        let views = stream.validated_lanes()?;
        let n = stream.lanes;
        let fills = stream.books.len().max(1);
        let decs = if lut::amortizes_fill(stream.count / fills) {
            LaneDecoders::for_stream_lut(stream, book)
        } else {
            LaneDecoders::for_stream(stream, book)
        };
        let dec_by_lane = decs.by_lane(n);
        let lane_results = pool::run_sharded(n, threads, |l| {
            let v = &views[l];
            let mut r =
                BitReader::with_len(&stream.bytes[v.range.clone()], v.bits as usize);
            let mut lane_out = vec![0u8; v.symbols];
            dec_by_lane[l]
                .decode_block_into(&mut r, &mut lane_out)
                .map(|()| lane_out)
        });
        let mut out = vec![0u8; stream.count];
        for (l, res) in lane_results.into_iter().enumerate() {
            // First error in lane order — the same lane `decode` trips on.
            let lane_out = res?;
            for (k, &sym) in lane_out.iter().enumerate() {
                out[l + k * n] = sym;
            }
        }
        Ok(out)
    }

    /// Lane-parallel encode (ISSUE 8): the per-lane payloads (strided
    /// gather + pair-fused [`BatchEncoder`]) are independent, so each
    /// builds on its own [`pool`] shard; the header/payload assembly
    /// runs on the caller's thread. Byte-identical to [`encode`] for
    /// every thread count (property-pinned below): shard content is a
    /// pure function of `(exps, book, lane)`, and assembly order is
    /// fixed. Shared-book (v1) form only — the per-lane-book encode is
    /// dominated by book construction, not payload bits.
    ///
    /// [`encode`]: LaneCodec::encode
    /// [`pool`]: crate::pool
    pub fn encode_par(&self, exps: &[u8], book: &CodeBook, threads: usize) -> LaneStream {
        let enc = BatchEncoder::new(book);
        assert!(
            exps.len() <= u32::MAX as usize,
            "lane stream supports at most u32::MAX symbols"
        );
        let lanes: Vec<(Vec<u8>, u32)> =
            pool::run_sharded(self.lanes, threads, |l| lane_payload(exps, self.lanes, l, &enc));
        let (payloads, lane_bits) = lanes.into_iter().unzip();
        self.assemble(exps.len(), payloads, lane_bits, None)
    }
}

/// One lane's payload: the round-robin substream (symbol `i` → lane
/// `i mod n`) through the pair-fused batch encoder. Pure in
/// `(exps, n, l, enc)` — the unit both the sequential assembly loop and
/// the [`pool`]-sharded [`LaneCodec::encode_par`] run, so the two paths
/// cannot drift.
///
/// [`pool`]: crate::pool
fn lane_payload(exps: &[u8], n: usize, l: usize, enc: &BatchEncoder) -> (Vec<u8>, u32) {
    let mut scratch: Vec<u8> = Vec::with_capacity(exps.len().div_ceil(n));
    scratch.extend(exps.iter().skip(l).step_by(n));
    let mut w = BitWriter::new();
    w.reserve_bits(scratch.len() as u64 * 2);
    enc.encode_block(&scratch, &mut w);
    assert!(
        w.len_bits() <= u32::MAX as usize,
        "lane payload exceeds the u32 bit-length header"
    );
    let bits = w.len_bits() as u32;
    (w.into_bytes(), bits)
}

/// Decoder tables for a stream: one per embedded book, or a single
/// shared one. The book-precedence and indexing rules (embedded v2
/// books win over the caller's shared book; shared ⇒ one table serves
/// every lane) live here *once*, shared by both software decode paths
/// and the `lexi-hw` cycle model — so a change to precedence cannot
/// desynchronize the paths the bit-exactness tests compare.
pub struct LaneDecoders {
    decs: Vec<CanonicalDecoder>,
}

impl LaneDecoders {
    /// Build the decoder tables for `stream`: its embedded per-lane
    /// books when present, else the shared `book`.
    pub fn for_stream(stream: &LaneStream, book: &CodeBook) -> Self {
        let decs = if stream.books.is_empty() {
            vec![book.decoder()]
        } else {
            stream.books.iter().map(|b| b.decoder()).collect()
        };
        LaneDecoders { decs }
    }

    /// Like [`for_stream`], but every decoder carries a multi-symbol
    /// decode LUT ([`CodeBook::lut_decoder`]) — unconditional, so tests
    /// and benches can force the LUT path on any stream size;
    /// [`LaneCodec::decode_lockstep`] applies the
    /// [`lut::LUT_DECODE_MIN_SYMBOLS`] threshold before calling this.
    ///
    /// [`for_stream`]: LaneDecoders::for_stream
    pub fn for_stream_lut(stream: &LaneStream, book: &CodeBook) -> Self {
        let decs = if stream.books.is_empty() {
            vec![book.lut_decoder()]
        } else {
            stream.books.iter().map(|b| b.lut_decoder()).collect()
        };
        LaneDecoders { decs }
    }

    /// The single decoder serving *every* lane, when the tables are
    /// shared (v1 shared-book streams) — `None` with per-lane books.
    /// The grouped lockstep loop uses this to pick its gather path: one
    /// shared [`MultiDecodeTable`] means all of a group's probes index
    /// the same entry slice.
    #[inline]
    pub fn shared(&self) -> Option<&CanonicalDecoder> {
        if self.decs.len() == 1 {
            Some(&self.decs[0])
        } else {
            None
        }
    }

    /// The decoder serving lane `l`.
    #[inline]
    pub fn lane(&self, l: usize) -> &CanonicalDecoder {
        if self.decs.len() == 1 {
            &self.decs[0]
        } else {
            &self.decs[l]
        }
    }

    /// Per-lane reference table for hot loops (one indexed load per
    /// symbol instead of a branch).
    pub fn by_lane(&self, lanes: usize) -> Vec<&CanonicalDecoder> {
        (0..lanes).map(|l| self.lane(l)).collect()
    }
}

/// One validated lane of a [`LaneStream`]: its payload location and the
/// symbol count it must yield. Produced by [`LaneStream::validated_lanes`],
/// shared by the software decoder and the `lexi-hw` lane model so format
/// validation lives in exactly one place.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LaneView {
    /// Lane index.
    pub lane: usize,
    /// Byte range of the payload within `LaneStream::bytes`.
    pub range: std::ops::Range<usize>,
    /// Payload bit length (excludes byte-alignment padding).
    pub bits: u32,
    /// Symbols this lane decodes to.
    pub symbols: usize,
}

/// A serialized `N`-lane stream.
///
/// Wire layout (all multi-byte fields big-endian):
///
/// ```text
/// v1: { lanes:u8           | count:u32 | lane_bits:u32 × lanes
///       | lane payloads, each byte-aligned }
/// v2: { 0x80|lanes:u8      | count:u32 | lane_bits:u32 × lanes
///       | book_bits:u16 × lanes | book headers, each byte-aligned
///       | lane payloads, each byte-aligned }
/// v3: { 0x00 | v1/v2 header (flags byte through book headers)
///       | lane_crc:u16 × lanes | header_crc:u16
///       | lane payloads, each byte-aligned }
/// ```
///
/// The top bit of the first byte ([`LANE_BOOKS_FLAG`]) selects v2:
/// per-lane codebook headers (as written by [`CodeBook::write_header`])
/// ride between the lane-bit table and the payloads, so multi-tenant
/// links can carry differently-distributed streams per lane. v1 bytes
/// are unchanged and parse identically under the v2 reader.
///
/// v3 (ISSUE 6) is escaped by a leading [`LANE_CRC_ESCAPE`] byte — an
/// invalid lane count to v1/v2 readers — and appends integrity metadata
/// to the header: one CRC-16 (CCITT-FALSE, [`crate::integrity`]) per
/// byte-aligned lane payload, then one over all preceding header bytes
/// (escape byte through the lane-CRC table). Verification order is
/// header first ([`from_bytes`]), payloads at decode time
/// ([`validated_lanes`]); both surface as
/// [`Error::Corrupt`], never as wrong symbols.
///
/// The per-lane bit lengths in the header are what lets a hardware
/// receiver point `N` decoders at their lanes before any decoding
/// happens — the same reason the flit format is flit-atomic.
///
/// [`from_bytes`]: LaneStream::from_bytes
/// [`validated_lanes`]: LaneStream::validated_lanes
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LaneStream {
    /// Lane count.
    pub lanes: usize,
    /// Total symbols across all lanes.
    pub count: usize,
    /// Per-lane payload bit lengths (excludes byte-alignment padding).
    pub lane_bits: Vec<u32>,
    /// Per-lane codebook header bit lengths (v2; empty ⇒ shared-book v1).
    pub book_bits: Vec<u16>,
    /// Parsed per-lane codebooks, parallel to `book_bits` (empty for v1).
    pub books: Vec<CodeBook>,
    /// Per-lane payload CRC-16s (v3; empty ⇒ unchecksummed v1/v2).
    pub lane_crc: Vec<u16>,
    /// The full serialized stream (header + payloads).
    pub bytes: Vec<u8>,
}

impl LaneStream {
    /// Header size in bytes: fixed fields + lane-bit table + (v2 only)
    /// the book-bit table and the byte-aligned book headers + (v3 only)
    /// the escape byte, lane-CRC table, and header CRC.
    pub fn header_bytes(&self) -> usize {
        let mut h = 5 + 4 * self.lanes;
        if !self.book_bits.is_empty() {
            h += 2 * self.book_bits.len();
            h += self
                .book_bits
                .iter()
                .map(|&b| (b as usize).div_ceil(8))
                .sum::<usize>();
        }
        if !self.lane_crc.is_empty() {
            h += 1 + 2 * self.lane_crc.len() + 2;
        }
        h
    }

    /// Symbols assigned to lane `l` (round-robin remainder arithmetic).
    pub fn lane_len(&self, l: usize) -> usize {
        debug_assert!(l < self.lanes);
        (self.count + self.lanes - 1 - l) / self.lanes
    }

    /// Byte range of lane `l`'s payload within [`bytes`].
    ///
    /// [`bytes`]: LaneStream::bytes
    pub fn lane_range(&self, l: usize) -> std::ops::Range<usize> {
        let mut off = self.header_bytes();
        for i in 0..l {
            off += (self.lane_bits[i] as usize).div_ceil(8);
        }
        off..off + (self.lane_bits[l] as usize).div_ceil(8)
    }

    /// Total wire size (header + payloads).
    pub fn wire_bytes(&self) -> usize {
        self.bytes.len()
    }

    /// Validate the header against the payload and return one
    /// [`LaneView`] per lane. This is the *only* place the lane format
    /// is trusted: it checks the lane count, the per-lane book table
    /// (count must match the lanes, each header length bounded by
    /// [`MAX_BOOK_HEADER_BITS`]), that every payload range lies inside
    /// `bytes`, and that each lane's symbol share fits its bit length
    /// (every codeword is ≥ 1 bit) — which bounds `count` by the actual
    /// wire size, so a hostile header cannot demand a multi-gigabyte
    /// output allocation. Checksummed (v3) streams additionally verify
    /// each lane payload's CRC-16 here — the single trust point every
    /// decode path flows through — returning
    /// [`Error::Corrupt`]`{block: 0, lane}` on mismatch.
    pub fn validated_lanes(&self) -> Result<Vec<LaneView>> {
        if self.lanes == 0 || self.lanes > MAX_LANES || self.lane_bits.len() != self.lanes {
            return Err(Error::InvalidParameter(format!(
                "malformed lane stream: {} lanes, {} lengths",
                self.lanes,
                self.lane_bits.len()
            )));
        }
        if !self.lane_crc.is_empty() && self.lane_crc.len() != self.lanes {
            return Err(Error::InvalidParameter(format!(
                "malformed lane stream: {} lane CRCs for {} lanes",
                self.lane_crc.len(),
                self.lanes
            )));
        }
        // Per-lane book table (v2): all-or-nothing, one book per lane,
        // every header length in range. Hostile counts/lengths die here,
        // before any decoder indexes `books[lane]`.
        if self.books.len() != self.book_bits.len() {
            return Err(Error::InvalidParameter(format!(
                "malformed lane stream: {} books for {} book lengths",
                self.books.len(),
                self.book_bits.len()
            )));
        }
        if !self.book_bits.is_empty() {
            if self.book_bits.len() != self.lanes {
                return Err(Error::InvalidParameter(format!(
                    "malformed lane stream: {} per-lane books for {} lanes",
                    self.book_bits.len(),
                    self.lanes
                )));
            }
            for (l, &bb) in self.book_bits.iter().enumerate() {
                if bb == 0 || bb as u32 > MAX_BOOK_HEADER_BITS {
                    return Err(Error::InvalidParameter(format!(
                        "lane {l}: book header of {bb} bits out of range 1..={MAX_BOOK_HEADER_BITS}"
                    )));
                }
            }
        }
        let mut views = Vec::with_capacity(self.lanes);
        let mut off = self.header_bytes();
        for l in 0..self.lanes {
            let bits = self.lane_bits[l];
            let end = off
                .checked_add((bits as usize).div_ceil(8))
                .ok_or_else(|| Error::InvalidParameter("lane offsets overflow".into()))?;
            if end > self.bytes.len() {
                return Err(Error::InvalidParameter(format!(
                    "lane {l} payload exceeds stream ({end} > {} bytes)",
                    self.bytes.len()
                )));
            }
            let symbols = self.lane_len(l);
            if symbols > bits as usize {
                return Err(Error::InvalidParameter(format!(
                    "lane {l}: {symbols} symbols cannot fit in {bits} payload bits"
                )));
            }
            views.push(LaneView {
                lane: l,
                range: off..end,
                bits,
                symbols,
            });
            off = end;
        }
        // Integrity last (v3): ranges are now known-sane, so each CRC
        // reads exactly its lane's byte-aligned payload. A mismatch is
        // transit corruption, not a malformed header.
        if !self.lane_crc.is_empty() {
            for v in &views {
                if crc16(&self.bytes[v.range.clone()]) != self.lane_crc[v.lane] {
                    return Err(Error::Corrupt {
                        block: 0,
                        lane: v.lane,
                    });
                }
            }
        }
        Ok(views)
    }

    /// Parse a serialized stream (inverse of the header
    /// [`LaneCodec::encode`] / [`LaneCodec::encode_per_lane`] write).
    /// Runs [`validated_lanes`], so the returned stream is safe to hand
    /// to any decoder. Hostile book tables are rejected with bounded
    /// work: allocations are capped by [`MAX_LANES`] books of
    /// [`MAX_BOOK_HEADER_BITS`] bits each, checked before parsing.
    ///
    /// Checksummed (v3, leading [`LANE_CRC_ESCAPE`]) streams verify the
    /// header CRC *before* any book header is parsed — a flipped bit
    /// anywhere in the header region surfaces as
    /// [`Error::Corrupt`]`{block: 0, lane: 0}`, not as a misparse.
    /// Lane payload CRCs are then verified by [`validated_lanes`].
    ///
    /// [`validated_lanes`]: LaneStream::validated_lanes
    pub fn from_bytes(bytes: Vec<u8>) -> Result<Self> {
        if bytes.len() < 5 {
            return Err(Error::InvalidParameter(
                "lane stream shorter than its fixed header".into(),
            ));
        }
        let v3 = bytes[0] == LANE_CRC_ESCAPE;
        // Offset of the flags/lanes byte; every later field shifts with it.
        let base = usize::from(v3);
        if bytes.len() < base + 5 {
            return Err(Error::InvalidParameter(
                "lane stream shorter than its fixed header".into(),
            ));
        }
        let flags = bytes[base];
        let has_books = flags & LANE_BOOKS_FLAG != 0;
        let lanes = (flags & !LANE_BOOKS_FLAG) as usize;
        if lanes == 0 || lanes > MAX_LANES {
            return Err(Error::InvalidParameter(format!(
                "lane count {lanes} out of range 1..={MAX_LANES}"
            )));
        }
        let count = u32::from_be_bytes(
            bytes[base + 1..base + 5].try_into().expect("4 bytes"),
        ) as usize;
        let header = base + 5 + 4 * lanes;
        if bytes.len() < header {
            return Err(Error::InvalidParameter(format!(
                "lane stream header truncated: {} < {header} bytes",
                bytes.len()
            )));
        }
        let lane_bits: Vec<u32> = (0..lanes)
            .map(|l| {
                u32::from_be_bytes(
                    bytes[header - 4 * (lanes - l)..header - 4 * (lanes - l) + 4]
                        .try_into()
                        .expect("4 bytes"),
                )
            })
            .collect();
        let mut book_bits: Vec<u16> = Vec::new();
        let mut book_region = header..header;
        if has_books {
            let table_end = header + 2 * lanes;
            if bytes.len() < table_end {
                return Err(if v3 {
                    // The header is CRC-protected: bytes missing from
                    // under it read as corruption, not a format quibble.
                    Error::Corrupt { block: 0, lane: 0 }
                } else {
                    Error::InvalidParameter(format!(
                        "lane stream book table truncated: {} < {table_end} bytes",
                        bytes.len()
                    ))
                });
            }
            book_bits = (0..lanes)
                .map(|l| {
                    u16::from_be_bytes(
                        bytes[header + 2 * l..header + 2 * l + 2]
                            .try_into()
                            .expect("2 bytes"),
                    )
                })
                .collect();
            // The blob extent is safe to *compute* before any validation
            // (u16 lengths cap it at 8 KiB/lane, no allocation happens);
            // the bound and truncation checks themselves wait until the
            // v3 header CRC has run, so a flipped header bit surfaces as
            // Corrupt rather than a bogus length complaint.
            let blobs: usize = book_bits
                .iter()
                .map(|&bb| (bb as usize).div_ceil(8))
                .sum();
            book_region = table_end..table_end + blobs;
        }
        // v3 integrity trailer: the lane-CRC table and the header CRC sit
        // after the book region. Verify the header CRC *before* parsing
        // any book — corrupted header bytes must surface as Corrupt, not
        // as a garbled codebook error or a misparse.
        let mut lane_crc: Vec<u16> = Vec::new();
        if v3 {
            let crc_at = book_region.end;
            let crc_end = crc_at + 2 * lanes + 2;
            if bytes.len() < crc_end {
                return Err(Error::Corrupt { block: 0, lane: 0 });
            }
            let stored = u16::from_be_bytes(
                bytes[crc_at + 2 * lanes..crc_end].try_into().expect("2 bytes"),
            );
            if crc16(&bytes[..crc_at + 2 * lanes]) != stored {
                return Err(Error::Corrupt { block: 0, lane: 0 });
            }
            lane_crc = (0..lanes)
                .map(|l| {
                    u16::from_be_bytes(
                        bytes[crc_at + 2 * l..crc_at + 2 * l + 2]
                            .try_into()
                            .expect("2 bytes"),
                    )
                })
                .collect();
        }
        let mut books: Vec<CodeBook> = Vec::new();
        if has_books {
            // Length bounds before any book parsing or allocation. A
            // v3 stream reaching here has a valid header CRC, so a
            // violation is a forgery, not transit corruption.
            for (l, &bb) in book_bits.iter().enumerate() {
                if bb == 0 || bb as u32 > MAX_BOOK_HEADER_BITS {
                    return Err(Error::InvalidParameter(format!(
                        "lane {l}: book header of {bb} bits out of range 1..={MAX_BOOK_HEADER_BITS}"
                    )));
                }
            }
            if bytes.len() < book_region.end {
                return Err(Error::InvalidParameter(format!(
                    "lane stream book headers truncated: {} < {} bytes",
                    bytes.len(),
                    book_region.end
                )));
            }
            let mut off = book_region.start;
            books = Vec::with_capacity(lanes);
            for &bb in &book_bits {
                let end = off + (bb as usize).div_ceil(8);
                let mut r = BitReader::with_len(&bytes[off..end], bb as usize);
                books.push(CodeBook::read_header(&mut r)?);
                off = end;
            }
        }
        let stream = LaneStream {
            lanes,
            count,
            lane_bits,
            book_bits,
            books,
            lane_crc,
            bytes,
        };
        stream.validated_lanes()?;
        Ok(stream)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::huffman::{compress_exponents, compress_with_book, decompress_exponents};
    use crate::proptest::check;
    use crate::stats::Histogram;

    fn book_of(data: &[u8]) -> CodeBook {
        CodeBook::lexi_default(&Histogram::from_bytes(data)).unwrap()
    }

    /// The scalar per-symbol oracle the batch paths must match bit-for-bit.
    fn scalar_encode(data: &[u8], book: &CodeBook) -> (Vec<u8>, usize) {
        let mut w = BitWriter::new();
        for &e in data {
            book.encode_symbol(e, &mut w);
        }
        let bits = w.len_bits();
        (w.into_bytes(), bits)
    }

    fn scalar_decode(bytes: &[u8], bits: usize, book: &CodeBook, n: usize) -> Vec<u8> {
        let dec = book.decoder();
        let mut r = BitReader::with_len(bytes, bits);
        (0..n).map(|_| dec.decode(&mut r).unwrap()).collect()
    }

    #[test]
    fn prop_batch_encode_is_bit_identical_to_scalar() {
        check("batch encode == scalar encode", 120, |g| {
            let n = g.usize(0..3000);
            // Skewed (few symbols, pair-LUT heavy) or uniform (ESC-heavy,
            // >32 distinct exponents → fallback path).
            let data = if g.bool(0.6) {
                let a = g.usize(1..50);
                g.skewed_bytes(n.max(1), a)
            } else {
                g.vec(n.max(1), |g| g.u8())
            };
            let book = book_of(&data);
            let (want_bytes, want_bits) = scalar_encode(&data, &book);
            let enc = BatchEncoder::new(&book);
            let mut w = BitWriter::new();
            enc.encode_block(&data, &mut w);
            assert_eq!(w.len_bits(), want_bits);
            assert_eq!(w.into_bytes(), want_bytes);
        });
    }

    #[test]
    fn prop_batch_encode_scalar_decode_roundtrip() {
        check("batch encode → scalar decode", 100, |g| {
            let n = g.usize(1..2500);
            let data = if g.bool(0.7) {
                let a = g.usize(1..40);
                g.skewed_bytes(n, a)
            } else {
                g.vec(n, |g| g.u8())
            };
            let book = book_of(&data);
            let enc = BatchEncoder::new(&book);
            let mut w = BitWriter::new();
            enc.encode_block(&data, &mut w);
            let bits = w.len_bits();
            let bytes = w.into_bytes();
            assert_eq!(scalar_decode(&bytes, bits, &book, data.len()), data);
        });
    }

    #[test]
    fn prop_scalar_encode_batch_decode_roundtrip() {
        check("scalar encode → batch decode", 100, |g| {
            let n = g.usize(1..2500);
            // ESC-heavy mix: >32 distinct exponents in most cases.
            let data = if g.bool(0.5) {
                g.vec(n, |g| g.u8())
            } else {
                let a = g.usize(33..120);
                g.skewed_bytes(n, a)
            };
            let book = book_of(&data);
            let (bytes, bits) = scalar_encode(&data, &book);
            let dec = book.decoder();
            let mut r = BitReader::with_len(&bytes, bits);
            let mut out = vec![0u8; data.len()];
            dec.decode_block_into(&mut r, &mut out).unwrap();
            assert_eq!(out, data);
            assert_eq!(r.remaining(), 0);
        });
    }

    #[test]
    fn single_symbol_stream_roundtrips() {
        let data = vec![127u8; 777];
        let book = book_of(&data);
        let enc = BatchEncoder::new(&book);
        let mut w = BitWriter::new();
        enc.encode_block(&data, &mut w);
        let bits = w.len_bits();
        let bytes = w.into_bytes();
        // 1-bit codes: 777 bits total.
        assert_eq!(bits, 777);
        let dec = book.decoder();
        let mut r = BitReader::with_len(&bytes, bits);
        let mut out = vec![0u8; data.len()];
        dec.decode_block_into(&mut r, &mut out).unwrap();
        assert_eq!(out, data);
    }

    #[test]
    fn prop_truncated_streams_error_not_panic() {
        check("batch decode rejects truncated tails", 80, |g| {
            let n = g.usize(2..800);
            let a = g.usize(1..60);
            let data = g.skewed_bytes(n, a);
            let book = book_of(&data);
            let (bytes, bits) = scalar_encode(&data, &book);
            let cut = g.usize(1..bits);
            let short_bits = bits - cut;
            let short_bytes = &bytes[..short_bits.div_ceil(8)];
            let dec = book.decoder();
            let mut r = BitReader::with_len(short_bytes, short_bits);
            let mut out = vec![0u8; data.len()];
            // Must error (the full count can no longer fit), never panic
            // or hand back a fabricated tail.
            assert!(dec.decode_block_into(&mut r, &mut out).is_err());
        });
    }

    #[test]
    fn prop_lane_roundtrip_all_lane_counts() {
        check("lane codec roundtrip lanes∈{1,2,4,8}", 80, |g| {
            let n = g.usize(0..2000);
            let data = if g.bool(0.7) {
                let a = g.usize(1..40);
                g.skewed_bytes(n.max(1), a)
            } else {
                g.vec(n.max(1), |g| g.u8())
            };
            let book = book_of(&data);
            for lanes in [1usize, 2, 4, 8] {
                let codec = LaneCodec::new(lanes).unwrap();
                let stream = codec.encode(&data, &book);
                assert_eq!(stream.lanes, lanes);
                assert_eq!(stream.count, data.len());
                let back = LaneCodec::decode(&stream, &book).unwrap();
                assert_eq!(back, data, "lanes {lanes}");
                // Serialization header survives a parse.
                let parsed = LaneStream::from_bytes(stream.bytes.clone()).unwrap();
                assert_eq!(parsed, stream);
                assert_eq!(LaneCodec::decode(&parsed, &book).unwrap(), data);
            }
        });
    }

    #[test]
    fn lane_stream_layout_is_as_documented() {
        let data: Vec<u8> = (0..100u32).map(|i| 120 + (i % 5) as u8).collect();
        let book = book_of(&data);
        let codec = LaneCodec::new(4).unwrap();
        let s = codec.encode(&data, &book);
        assert_eq!(s.bytes[0], 4);
        assert_eq!(
            u32::from_be_bytes(s.bytes[1..5].try_into().unwrap()),
            100
        );
        assert_eq!(s.header_bytes(), 5 + 16);
        assert_eq!(s.lane_len(0), 25);
        assert_eq!(s.lane_len(3), 25);
        let total: usize = (0..4).map(|l| s.lane_range(l).len()).sum();
        assert_eq!(s.header_bytes() + total, s.bytes.len());
    }

    #[test]
    fn hostile_count_header_rejected() {
        // lanes=1, count=u32::MAX, lane_bits=0: a 13-byte stream whose
        // header demands a 4 GiB output. validated_lanes must reject it
        // (count bounded by payload bits) before any allocation.
        let mut bytes = vec![1u8];
        bytes.extend_from_slice(&u32::MAX.to_be_bytes());
        bytes.extend_from_slice(&0u32.to_be_bytes());
        assert!(LaneStream::from_bytes(bytes.clone()).is_err());
        // Same header smuggled around from_bytes: all decoders refuse.
        let stream = LaneStream {
            lanes: 1,
            count: u32::MAX as usize,
            lane_bits: vec![0],
            book_bits: vec![],
            books: vec![],
            lane_crc: vec![],
            bytes,
        };
        let book = book_of(&[7u8; 16]);
        assert!(LaneCodec::decode(&stream, &book).is_err());
        assert!(LaneCodec::decode_lockstep(&stream, &book).is_err());
    }

    #[test]
    fn lane_stream_truncation_rejected() {
        let data = vec![9u8; 300];
        let book = book_of(&data);
        let s = LaneCodec::new(2).unwrap().encode(&data, &book);
        for cut in [1usize, 4, s.bytes.len() - s.header_bytes() + 1] {
            let mut short = s.bytes.clone();
            short.truncate(s.bytes.len().saturating_sub(cut));
            assert!(LaneStream::from_bytes(short).is_err(), "cut {cut}");
        }
        assert!(LaneCodec::new(0).is_err());
        assert!(LaneCodec::new(MAX_LANES + 1).is_err());
    }

    #[test]
    fn prop_lockstep_matches_lane_at_a_time_and_scalar() {
        // The tentpole equivalence: lockstep ⇔ lane-at-a-time ⇔ scalar
        // order, across lane counts, skewed and ESC-heavy alphabets.
        check("lockstep == lane-at-a-time == scalar", 100, |g| {
            let n = g.usize(1..2500);
            let data = match g.usize(0..3) {
                0 => {
                    let a = g.usize(1..32);
                    g.skewed_bytes(n, a)
                }
                // ESC-heavy: >32 distinct exponents force escape codes.
                1 => {
                    let a = g.usize(33..140);
                    g.skewed_bytes(n, a)
                }
                _ => g.vec(n, |g| g.u8()),
            };
            let book = book_of(&data);
            for lanes in [1usize, 2, 4, 8] {
                let codec = LaneCodec::new(lanes).unwrap();
                let stream = codec.encode(&data, &book);
                let lane_at_a_time = LaneCodec::decode(&stream, &book).unwrap();
                let lockstep = LaneCodec::decode_lockstep(&stream, &book).unwrap();
                assert_eq!(lockstep, data, "lockstep lanes {lanes}");
                assert_eq!(lane_at_a_time, lockstep, "paths diverge at lanes {lanes}");
                // Force the multi-symbol LUT path regardless of the
                // stream-size threshold (ISSUE 4): still bit-exact.
                let lut = LaneCodec::decode_lockstep_with(
                    &stream,
                    &LaneDecoders::for_stream_lut(&stream, &book),
                )
                .unwrap();
                assert_eq!(lut, data, "lut lockstep diverged at lanes {lanes}");
                assert_eq!(
                    LaneCodec::decode_lockstep_scalar(&stream, &book).unwrap(),
                    data,
                    "scalar lockstep baseline diverged at lanes {lanes}"
                );
            }
        });
    }

    #[test]
    fn prop_lockstep_rejects_truncated_tails() {
        check("lockstep errors on truncated lanes", 60, |g| {
            let n = g.usize(8..1200);
            let a = g.usize(1..60);
            let data = g.skewed_bytes(n, a);
            let book = book_of(&data);
            let lanes = [1usize, 2, 4, 8][g.usize(0..4)];
            let stream = LaneCodec::new(lanes).unwrap().encode(&data, &book);
            // Shrink one lane's advertised bit length: the missing tail
            // must surface as an error on both decode paths, not a panic
            // or fabricated symbols.
            let mut short = stream.clone();
            let l = g.usize(0..lanes);
            if short.lane_bits[l] == 0 {
                return;
            }
            let cut = g.usize(1..short.lane_bits[l] as usize + 1) as u32;
            short.lane_bits[l] -= cut;
            let a = LaneCodec::decode(&short, &book);
            let b = LaneCodec::decode_lockstep(&short, &book);
            assert!(a.is_err(), "lane-at-a-time accepted a truncated lane");
            assert!(b.is_err(), "lockstep accepted a truncated lane");
            // And with the multi-LUT forced on: the LUT only fires on
            // full-fit entries, so truncation errors survive unchanged.
            let c = LaneCodec::decode_lockstep_with(
                &short,
                &LaneDecoders::for_stream_lut(&short, &book),
            );
            assert!(c.is_err(), "lut lockstep accepted a truncated lane");
        });
    }

    #[test]
    fn prop_per_lane_books_roundtrip() {
        // Multi-tenant shape: lane l's substream is drawn from its own
        // distribution, encoded under its own codebook, and the books
        // ride in the v2 header — decode needs no side channel.
        check("per-lane codebooks roundtrip", 60, |g| {
            let lanes = [1usize, 2, 4, 8][g.usize(0..4)];
            let n = g.usize(lanes..2000);
            let bases: Vec<u8> = (0..lanes).map(|_| g.u8()).collect();
            // Symbol i belongs to tenant i % lanes, clustered near that
            // tenant's base so per-lane distributions genuinely differ.
            let data: Vec<u8> = (0..n)
                .map(|i| {
                    let mut off = 0u8;
                    while off < 6 && g.bool(0.4) {
                        off += 1;
                    }
                    bases[i % lanes].wrapping_add(off)
                })
                .collect();
            let codec = LaneCodec::new(lanes).unwrap();
            let books: Vec<CodeBook> = (0..lanes)
                .map(|l| {
                    let lane_syms: Vec<u8> =
                        data.iter().copied().skip(l).step_by(lanes).collect();
                    book_of(&lane_syms)
                })
                .collect();
            let stream = codec.encode_per_lane(&data, &books).unwrap();
            assert_eq!(stream.books.len(), lanes);
            assert_eq!(stream.bytes[0] & LANE_BOOKS_FLAG, LANE_BOOKS_FLAG);
            // The `book` argument is ignored when books are embedded: pass
            // a deliberately wrong shared book.
            let wrong = book_of(&[1u8, 2, 3]);
            assert_eq!(LaneCodec::decode(&stream, &wrong).unwrap(), data);
            assert_eq!(LaneCodec::decode_lockstep(&stream, &wrong).unwrap(), data);
            // Embedded books drive the per-lane multi-LUTs too.
            assert_eq!(
                LaneCodec::decode_lockstep_with(
                    &stream,
                    &LaneDecoders::for_stream_lut(&stream, &wrong),
                )
                .unwrap(),
                data
            );
            // And the wire bytes reparse to an identical stream.
            let parsed = LaneStream::from_bytes(stream.bytes.clone()).unwrap();
            assert_eq!(parsed, stream);
            assert_eq!(LaneCodec::decode_lockstep(&parsed, &wrong).unwrap(), data);
        });
    }

    #[test]
    fn prop_hostile_book_headers_rejected_cheaply() {
        // Fuzz the v2 book region: flipped bytes and forged lengths must
        // either parse to a consistent stream or be rejected — never
        // panic, and never allocate beyond the bounded book table.
        check("hostile per-lane-book headers", 80, |g| {
            let lanes = [1usize, 2, 4][g.usize(0..3)];
            let n = g.usize(lanes..400);
            let a = g.usize(1..20);
            let data = g.skewed_bytes(n, a);
            let books: Vec<CodeBook> = (0..lanes).map(|_| book_of(&data)).collect();
            let stream = LaneCodec::new(lanes)
                .unwrap()
                .encode_per_lane(&data, &books)
                .unwrap();
            let mut bytes = stream.bytes.clone();
            match g.usize(0..3) {
                0 => {
                    // Garble bytes inside the book region.
                    let lo = 5 + 4 * lanes;
                    let hi = stream.header_bytes();
                    for _ in 0..g.usize(1..6) {
                        let i = g.usize(lo..hi);
                        bytes[i] ^= g.u8() | 1;
                    }
                }
                1 => {
                    // Forge a book length: zero, huge, or past the stream.
                    let l = g.usize(0..lanes);
                    let forged: u16 = match g.usize(0..3) {
                        0 => 0,
                        1 => u16::MAX,
                        _ => MAX_BOOK_HEADER_BITS as u16 + g.u16() % 1000 + 1,
                    };
                    let at = 5 + 4 * lanes + 2 * l;
                    bytes[at..at + 2].copy_from_slice(&forged.to_be_bytes());
                }
                _ => {
                    // Truncate inside the book region.
                    let keep = g.usize(5..stream.header_bytes());
                    bytes.truncate(keep);
                }
            }
            // Must not panic; errors are expected, the rare survivor must
            // still satisfy its own validation.
            if let Ok(s) = LaneStream::from_bytes(bytes) {
                assert!(s.validated_lanes().is_ok());
            }
        });
    }

    #[test]
    fn empty_and_single_symbol_lane_streams() {
        // Regression (ISSUE 2 satellite): zero-symbol and one-symbol
        // streams round-trip on every path at every lane count.
        let book = book_of(&[9u8, 9, 9, 10]);
        for lanes in [1usize, 2, 4, 8] {
            let codec = LaneCodec::new(lanes).unwrap();
            for data in [&[][..], &[9u8][..]] {
                let stream = codec.encode(data, &book);
                assert_eq!(stream.count, data.len());
                assert_eq!(
                    LaneCodec::decode(&stream, &book).unwrap(),
                    data,
                    "lane-at-a-time lanes {lanes}"
                );
                assert_eq!(
                    LaneCodec::decode_lockstep(&stream, &book).unwrap(),
                    data,
                    "lockstep lanes {lanes}"
                );
                let parsed = LaneStream::from_bytes(stream.bytes.clone()).unwrap();
                assert_eq!(parsed, stream);
            }
        }
    }

    #[test]
    fn checksummed_stream_layout_and_roundtrip() {
        // v3 layout pin (ISSUE 6): escape byte, flags at offset 1, the
        // v1/v2 header body, lane-CRC table, header CRC, payloads.
        let data: Vec<u8> = (0..100u32).map(|i| 120 + (i % 5) as u8).collect();
        let book = book_of(&data);
        let codec = LaneCodec::new(4).unwrap().with_checksums();
        let s = codec.encode(&data, &book);
        assert_eq!(s.bytes[0], LANE_CRC_ESCAPE);
        assert_eq!(s.bytes[1], 4);
        assert_eq!(u32::from_be_bytes(s.bytes[2..6].try_into().unwrap()), 100);
        // escape + (5 + 4·lanes) + 2·lanes lane CRCs + 2 header CRC.
        assert_eq!(s.header_bytes(), 1 + 5 + 16 + 8 + 2);
        assert_eq!(s.lane_crc.len(), 4);
        for l in 0..4 {
            assert_eq!(crc16(&s.bytes[s.lane_range(l)]), s.lane_crc[l]);
        }
        // Both decode paths verify and round-trip.
        assert_eq!(LaneCodec::decode(&s, &book).unwrap(), data);
        assert_eq!(LaneCodec::decode_lockstep(&s, &book).unwrap(), data);
        // The wire bytes reparse to an identical stream.
        let parsed = LaneStream::from_bytes(s.bytes.clone()).unwrap();
        assert_eq!(parsed, s);
        // The payload bits are identical to the unchecksummed encode —
        // v3 only *wraps* the stream, it never changes the coded bits.
        let plain = LaneCodec::new(4).unwrap().encode(&data, &book);
        assert_eq!(
            &s.bytes[s.header_bytes()..],
            &plain.bytes[plain.header_bytes()..]
        );
    }

    #[test]
    fn checksums_off_is_byte_identical_to_v1v2() {
        // The default codec never emits v3 bytes: every pre-ISSUE-6 pin
        // (stream bytes, flit payloads, bench inputs) holds verbatim.
        let data = vec![42u8; 333];
        let book = book_of(&data);
        let codec = LaneCodec::new(2).unwrap();
        assert!(!codec.checksummed());
        let s = codec.encode(&data, &book);
        assert_eq!(s.bytes[0], 2);
        assert!(s.lane_crc.is_empty());
        assert_eq!(s.header_bytes(), 5 + 8);
        // And a v2 per-lane-book stream keeps its flag byte at offset 0.
        let books = vec![book.clone(), book.clone()];
        let v2 = codec.encode_per_lane(&data, &books).unwrap();
        assert_eq!(v2.bytes[0], 2 | LANE_BOOKS_FLAG);
        assert!(v2.lane_crc.is_empty());
    }

    #[test]
    fn prop_single_bit_flip_roundtrips_or_errors() {
        // ISSUE 6 satellite: for EVERY bit position in a checksummed v3
        // stream (v2 shape: embedded per-lane books), a single flipped
        // bit either leaves the decode a perfect round-trip (impossible
        // here, but allowed by contract) or surfaces as a typed error —
        // never a panic, never wrong symbols. Single-bit flips past the
        // escape byte are specifically Corrupt: CRC-16 has Hamming
        // distance ≥ 2 at these lengths, so none escape.
        check("v3 single-bit flips detected", 6, |g| {
            let lanes = [1usize, 2, 4][g.usize(0..3)];
            let n = g.usize(lanes.max(2)..60);
            let a = g.usize(1..12);
            let data = g.skewed_bytes(n, a);
            let books: Vec<CodeBook> = (0..lanes).map(|_| book_of(&data)).collect();
            let stream = LaneCodec::new(lanes)
                .unwrap()
                .with_checksums()
                .encode_per_lane(&data, &books)
                .unwrap();
            let shared = book_of(&data);
            for pos in 0..stream.bytes.len() * 8 {
                let mut dirty = stream.bytes.clone();
                dirty[pos / 8] ^= 1 << (pos % 8);
                match LaneStream::from_bytes(dirty) {
                    Ok(s) => {
                        // Reachable only via the escape byte aliasing to
                        // a v1/v2 header; any symbols produced must be
                        // the originals.
                        if let Ok(out) = LaneCodec::decode(&s, &shared) {
                            assert_eq!(out, data, "bit {pos}: wrong symbols undetected");
                        }
                    }
                    Err(e) => {
                        // Flips in the escape or flags byte can reshape
                        // the parse geometry (different version, lane
                        // count 0) and die as InvalidParameter; from the
                        // count field onward the header CRC and lane
                        // CRCs own every bit, so the error is Corrupt.
                        if pos >= 16 {
                            assert!(
                                matches!(e, Error::Corrupt { .. }),
                                "bit {pos}: expected Corrupt, got {e}"
                            );
                        }
                    }
                }
            }
        });
    }

    #[test]
    fn hostile_v3_headers_rejected() {
        let data = vec![9u8; 120];
        let book = book_of(&data);
        let s = LaneCodec::new(2).unwrap().with_checksums().encode(&data, &book);
        // Truncations anywhere in the stream: error, never panic.
        for keep in 0..s.bytes.len() {
            assert!(
                LaneStream::from_bytes(s.bytes[..keep].to_vec()).is_err(),
                "keep {keep}"
            );
        }
        // A bare escape byte with a zero lane count.
        assert!(LaneStream::from_bytes(vec![0u8; 8]).is_err());
        // Stream object smuggled around from_bytes with a short CRC
        // table: validated_lanes refuses before any CRC is indexed.
        let mut forged = s.clone();
        forged.lane_crc.pop();
        assert!(LaneCodec::decode(&forged, &book).is_err());
        // Corrupted lane payload caught by the lane CRC on BOTH decode
        // paths, with the lane identified.
        let mut dirty = s.clone();
        let at = dirty.lane_range(1).start;
        dirty.bytes[at] ^= 0x10;
        assert_eq!(
            LaneCodec::decode(&dirty, &book).unwrap_err(),
            Error::Corrupt { block: 0, lane: 1 }
        );
        assert_eq!(
            LaneCodec::decode_lockstep(&dirty, &book).unwrap_err(),
            Error::Corrupt { block: 0, lane: 1 }
        );
    }

    #[test]
    fn compressed_block_sizes_unchanged_by_rewire() {
        // compress_with_book routes through the batch engine; its output
        // must be byte-identical to header + count + scalar payload.
        let data: Vec<u8> = (0..5000u32).map(|i| (i % 41) as u8).collect();
        let book = book_of(&data);
        let mut w = BitWriter::new();
        book.write_header(&mut w);
        w.put(data.len() as u64, 32);
        for &e in &data {
            book.encode_symbol(e, &mut w);
        }
        let want_bits = w.len_bits();
        let want_bytes = w.into_bytes();
        let block = compress_with_book(&data, &book).unwrap();
        assert_eq!(block.bits, want_bits);
        assert_eq!(block.bytes, want_bytes);
        // And the public roundtrip still holds.
        let blk2 = compress_exponents(&data).unwrap();
        assert_eq!(decompress_exponents(&blk2).unwrap(), data);
    }

    /// Random stream in any wire version (v1 shared-book, v2 per-lane
    /// books, v3 checksummed), for the ISSUE 8 equivalence tests.
    fn any_version_stream(
        g: &mut crate::proptest::Gen,
        data: &[u8],
        lanes: usize,
        book: &CodeBook,
    ) -> LaneStream {
        let mut codec = LaneCodec::new(lanes).unwrap();
        if g.bool(0.3) {
            codec = codec.with_checksums();
        }
        if g.bool(0.4) {
            let books: Vec<CodeBook> = (0..lanes).map(|_| book.clone()).collect();
            codec.encode_per_lane(data, &books).unwrap()
        } else {
            codec.encode(data, book)
        }
    }

    #[test]
    fn prop_swar_lockstep_is_bit_identical_to_reference() {
        // ISSUE 8 tentpole pin: the grouped SWAR loop must reproduce the
        // reference per-lane visit loop exactly — same symbols over every
        // lane count (partial groups, multiple groups), wire version, and
        // decoder table choice (scalar kernels and per-lane multi-LUTs).
        check("swar lockstep == reference lockstep", 60, |g| {
            let n = g.usize(1..2500);
            let data = match g.usize(0..3) {
                0 => {
                    let a = g.usize(1..32);
                    g.skewed_bytes(n, a)
                }
                1 => {
                    let a = g.usize(33..140);
                    g.skewed_bytes(n, a)
                }
                _ => g.vec(n, |g| g.u8()),
            };
            let book = book_of(&data);
            for lanes in [1usize, 2, 3, 7, 8, 11, 16] {
                let stream = any_version_stream(g, &data, lanes, &book);
                for lut_on in [false, true] {
                    let decs = if lut_on {
                        LaneDecoders::for_stream_lut(&stream, &book)
                    } else {
                        LaneDecoders::for_stream(&stream, &book)
                    };
                    let reference = LaneCodec::decode_lockstep_with(&stream, &decs).unwrap();
                    let swar = LaneCodec::decode_lockstep_swar(&stream, &decs).unwrap();
                    assert_eq!(reference, data, "reference lanes {lanes} lut {lut_on}");
                    assert_eq!(swar, reference, "swar diverged lanes {lanes} lut {lut_on}");
                }
                // And the public dispatch (which now routes through the
                // SWAR loop) still equals the lane-at-a-time decoder.
                assert_eq!(
                    LaneCodec::decode_lockstep(&stream, &book).unwrap(),
                    LaneCodec::decode(&stream, &book).unwrap(),
                    "dispatch lanes {lanes}"
                );
            }
        });
    }

    #[test]
    fn prop_swar_lockstep_errors_identically_to_reference() {
        // Truncated and corrupted streams: the SWAR loop must surface the
        // *identical typed error* — same variant, same offsets, same lane
        // — as the reference loop, across versions and table choices.
        check("swar lockstep errors == reference errors", 60, |g| {
            let n = g.usize(8..1500);
            let a = g.usize(1..60);
            let data = g.skewed_bytes(n, a);
            let book = book_of(&data);
            let lanes = [1usize, 2, 3, 7, 8, 16][g.usize(0..6)];
            let mut stream = any_version_stream(g, &data, lanes, &book);
            // Mutate: shrink a lane's advertised bits, or flip payload
            // bytes (v3 catches the flip as Corrupt in validation; v1/v2
            // mis-decode into a typed kernel error or succeed — every
            // outcome must simply match the reference path's).
            if g.bool(0.5) {
                let l = g.usize(0..lanes);
                if stream.lane_bits[l] == 0 {
                    return;
                }
                let cut = g.usize(1..stream.lane_bits[l] as usize + 1) as u32;
                stream.lane_bits[l] -= cut;
            } else {
                let payload_at = stream.header_bytes();
                if payload_at >= stream.bytes.len() {
                    return;
                }
                for _ in 0..g.usize(1..4) {
                    let i = g.usize(payload_at..stream.bytes.len());
                    stream.bytes[i] ^= g.u8() | 1;
                }
            }
            for lut_on in [false, true] {
                let decs = if lut_on {
                    LaneDecoders::for_stream_lut(&stream, &book)
                } else {
                    LaneDecoders::for_stream(&stream, &book)
                };
                let reference = LaneCodec::decode_lockstep_with(&stream, &decs);
                let swar = LaneCodec::decode_lockstep_swar(&stream, &decs);
                assert_eq!(
                    reference, swar,
                    "result diverged (lanes {lanes}, lut {lut_on})"
                );
            }
        });
    }

    #[test]
    fn prop_parallel_codec_is_thread_count_invariant() {
        // ISSUE 8 determinism contract: encode_par and decode_par produce
        // byte-identical results for every thread count, and equal the
        // sequential paths exactly.
        check("encode_par/decode_par T-invariant", 30, |g| {
            let n = g.usize(1..3000);
            let data = if g.bool(0.7) {
                let a = g.usize(1..40);
                g.skewed_bytes(n, a)
            } else {
                g.vec(n, |g| g.u8())
            };
            let book = book_of(&data);
            for lanes in [1usize, 3, 8] {
                let codec = LaneCodec::new(lanes).unwrap();
                let sequential = codec.encode(&data, &book);
                for t in [1usize, 2, 8] {
                    let par = codec.encode_par(&data, &book, t);
                    assert_eq!(
                        par.bytes, sequential.bytes,
                        "encode_par bytes differ (lanes {lanes}, T={t})"
                    );
                    assert_eq!(par, sequential, "encode_par stream differs (T={t})");
                    assert_eq!(
                        LaneCodec::decode_par(&sequential, &book, t).unwrap(),
                        data,
                        "decode_par (lanes {lanes}, T={t})"
                    );
                }
            }
        });
    }

    #[test]
    fn prop_decode_par_error_matches_lane_at_a_time() {
        // decode_par's surfaced error is the first failing lane in lane
        // index order — the exact error decode() reports — for truncated
        // lanes and corrupted payloads, at every thread count.
        check("decode_par errors == decode errors", 40, |g| {
            let n = g.usize(8..1200);
            let a = g.usize(1..60);
            let data = g.skewed_bytes(n, a);
            let book = book_of(&data);
            let lanes = [1usize, 2, 4, 8][g.usize(0..4)];
            let mut stream = any_version_stream(g, &data, lanes, &book);
            if g.bool(0.5) {
                let l = g.usize(0..lanes);
                if stream.lane_bits[l] == 0 {
                    return;
                }
                let cut = g.usize(1..stream.lane_bits[l] as usize + 1) as u32;
                stream.lane_bits[l] -= cut;
            } else {
                let payload_at = stream.header_bytes();
                if payload_at >= stream.bytes.len() {
                    return;
                }
                let i = g.usize(payload_at..stream.bytes.len());
                stream.bytes[i] ^= g.u8() | 1;
            }
            let sequential = LaneCodec::decode(&stream, &book);
            for t in [1usize, 2, 8] {
                let par = LaneCodec::decode_par(&stream, &book, t);
                match (&sequential, &par) {
                    (Ok(a), Ok(b)) => assert_eq!(a, b, "outputs diverged (T={t})"),
                    (Err(ea), Err(eb)) => {
                        assert_eq!(ea, eb, "error details diverged (T={t})")
                    }
                    _ => panic!(
                        "ok/err divergence (T={t}): sequential {sequential:?} vs par {par:?}"
                    ),
                }
            }
        });
    }
}
