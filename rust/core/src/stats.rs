//! Entropy and distribution statistics for BF16 field streams.
//!
//! Implements the paper's §3 profiling: Shannon entropy of the exponent /
//! mantissa / sign streams, distinct-value counts, and the ideal
//! (entropy-bound) compression ratios those imply.

use crate::bf16::Bf16;

/// A 256-bin histogram over byte symbols (exponents or mantissas).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Histogram {
    pub counts: [u64; 256],
    pub total: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            counts: [0; 256],
            total: 0,
        }
    }
}

impl Histogram {
    /// Build from a byte stream.
    pub fn from_bytes(bytes: &[u8]) -> Self {
        let mut h = Histogram::default();
        for &b in bytes {
            h.counts[b as usize] += 1;
        }
        h.total = bytes.len() as u64;
        h
    }

    /// Accumulate one observation.
    #[inline]
    pub fn add(&mut self, symbol: u8, count: u64) {
        self.counts[symbol as usize] += count;
        self.total += count;
    }

    /// Merge another histogram into this one.
    pub fn merge(&mut self, other: &Histogram) {
        for i in 0..256 {
            self.counts[i] += other.counts[i];
        }
        self.total += other.total;
    }

    /// Shannon entropy in bits per symbol.
    pub fn entropy_bits(&self) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let n = self.total as f64;
        let mut h = 0.0;
        for &c in &self.counts {
            if c > 0 {
                let p = c as f64 / n;
                h -= p * p.log2();
            }
        }
        h
    }

    /// Number of symbols with non-zero count (the paper reports <32 for
    /// exponent streams).
    pub fn distinct(&self) -> usize {
        self.counts.iter().filter(|&&c| c > 0).count()
    }

    /// Symbols sorted by descending count (ties broken by symbol value),
    /// restricted to non-zero entries. This is exactly the input order the
    /// hardware bitonic sorter must produce.
    pub fn sorted_symbols(&self) -> Vec<(u8, u64)> {
        let mut v: Vec<(u8, u64)> = (0..256u16)
            .filter(|&s| self.counts[s as usize] > 0)
            .map(|s| (s as u8, self.counts[s as usize]))
            .collect();
        v.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        v
    }

    /// The `k` most frequent symbols' share of total mass — the quantity
    /// that determines lane-cache hit rates (Fig 4).
    pub fn top_k_mass(&self, k: usize) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let top: u64 = self.sorted_symbols().iter().take(k).map(|&(_, c)| c).sum();
        top as f64 / self.total as f64
    }
}

/// Per-field profiling summary of a BF16 tensor (one row of Fig 1a).
#[derive(Clone, Debug)]
pub struct FieldProfile {
    pub count: usize,
    pub exp_entropy_bits: f64,
    pub mant_entropy_bits: f64,
    pub sign_entropy_bits: f64,
    pub exp_distinct: usize,
    pub exp_hist: Histogram,
}

impl FieldProfile {
    /// Profile a BF16 stream.
    pub fn of(values: &[Bf16]) -> Self {
        let mut exp_hist = Histogram::default();
        let mut mant_hist = Histogram::default();
        let mut ones = 0u64;
        for &v in values {
            exp_hist.add(v.exponent(), 1);
            mant_hist.add(v.mantissa(), 1);
            ones += v.sign() as u64;
        }
        let n = values.len() as u64;
        let sign_entropy_bits = if n == 0 {
            0.0
        } else {
            binary_entropy(ones as f64 / n as f64)
        };
        FieldProfile {
            count: values.len(),
            exp_entropy_bits: exp_hist.entropy_bits(),
            mant_entropy_bits: mant_hist.entropy_bits(),
            sign_entropy_bits,
            exp_distinct: exp_hist.distinct(),
            exp_hist,
        }
    }

    /// Ideal exponent compression ratio: 8 bits / entropy.
    pub fn ideal_exp_cr(&self) -> f64 {
        if self.exp_entropy_bits <= 0.0 {
            f64::INFINITY
        } else {
            8.0 / self.exp_entropy_bits
        }
    }

    /// Ideal whole-value compression ratio if only exponents are coded:
    /// 16 / (1 + 7 + H(exp)).
    pub fn ideal_value_cr(&self) -> f64 {
        16.0 / (8.0 + self.exp_entropy_bits)
    }
}

/// Binary entropy H(p) in bits.
pub fn binary_entropy(p: f64) -> f64 {
    if p <= 0.0 || p >= 1.0 {
        0.0
    } else {
        -p * p.log2() - (1.0 - p) * (1.0 - p).log2()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Rng;

    #[test]
    fn entropy_of_uniform_bytes_is_8() {
        let bytes: Vec<u8> = (0..=255u8).cycle().take(256 * 64).collect();
        let h = Histogram::from_bytes(&bytes);
        assert!((h.entropy_bits() - 8.0).abs() < 1e-9);
        assert_eq!(h.distinct(), 256);
    }

    #[test]
    fn entropy_of_constant_is_0() {
        let h = Histogram::from_bytes(&[42u8; 1000]);
        assert_eq!(h.entropy_bits(), 0.0);
        assert_eq!(h.distinct(), 1);
        assert_eq!(h.top_k_mass(1), 1.0);
    }

    #[test]
    fn merge_matches_concat() {
        let a = Histogram::from_bytes(&[1, 2, 3, 3]);
        let b = Histogram::from_bytes(&[3, 4]);
        let mut m = a.clone();
        m.merge(&b);
        assert_eq!(m, Histogram::from_bytes(&[1, 2, 3, 3, 3, 4]));
    }

    #[test]
    fn gaussian_bf16_exponents_have_low_entropy() {
        // The paper's core observation: exponents of well-scaled tensors
        // carry < 3 bits of entropy and < 32 distinct values dominate.
        let mut rng = Rng::new(99);
        let vals: Vec<Bf16> = (0..100_000)
            .map(|_| Bf16::from_f32(rng.normal_with(0.0, 0.02) as f32))
            .collect();
        let p = FieldProfile::of(&vals);
        assert!(
            p.exp_entropy_bits < 4.5,
            "exp entropy {}",
            p.exp_entropy_bits
        );
        assert!(
            p.mant_entropy_bits > 6.5,
            "mant entropy {}",
            p.mant_entropy_bits
        );
        // ≥99% of mass within the 32 most frequent exponents.
        assert!(p.exp_hist.top_k_mass(32) > 0.99);
    }

    #[test]
    fn sorted_symbols_descending() {
        let h = Histogram::from_bytes(&[5, 5, 5, 7, 7, 9]);
        assert_eq!(h.sorted_symbols(), vec![(5, 3), (7, 2), (9, 1)]);
    }

    #[test]
    fn binary_entropy_extremes() {
        assert_eq!(binary_entropy(0.0), 0.0);
        assert_eq!(binary_entropy(1.0), 0.0);
        assert!((binary_entropy(0.5) - 1.0).abs() < 1e-12);
    }
}
