//! Minimal property-based testing driver.
//!
//! The offline crate set has no `proptest`, so this module provides the
//! slice of it the test suites need: run a property over many seeded random
//! cases, and on failure report the exact seed + case index so the failure
//! is reproducible by construction.
//!
//! ```
//! use lexi_core::proptest::{check, Gen};
//! check("addition commutes", 200, |g| {
//!     let a = g.u64(0..1 << 32);
//!     let b = g.u64(0..1 << 32);
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use crate::prng::Rng;
use std::ops::Range;

/// Case-local generator handed to properties.
pub struct Gen {
    rng: Rng,
    /// Seed of this particular case, for failure reports.
    pub case_seed: u64,
}

impl Gen {
    /// Uniform `u64` in `range`.
    pub fn u64(&mut self, range: Range<u64>) -> u64 {
        assert!(range.start < range.end, "empty range");
        range.start + self.rng.below(range.end - range.start)
    }

    /// Uniform `usize` in `range`.
    pub fn usize(&mut self, range: Range<usize>) -> usize {
        self.u64(range.start as u64..range.end as u64) as usize
    }

    /// Uniform `u8`.
    pub fn u8(&mut self) -> u8 {
        (self.rng.next_u32() & 0xff) as u8
    }

    /// Uniform `u16`.
    pub fn u16(&mut self) -> u16 {
        (self.rng.next_u32() & 0xffff) as u16
    }

    /// Bernoulli trial.
    pub fn bool(&mut self, p: f64) -> bool {
        self.rng.chance(p)
    }

    /// Uniform float in `[lo, hi)`.
    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range(lo, hi)
    }

    /// Standard normal.
    pub fn normal(&mut self) -> f64 {
        self.rng.normal()
    }

    /// A vector of `len` items built by `f`.
    pub fn vec<T>(&mut self, len: usize, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        (0..len).map(|_| f(self)).collect()
    }

    /// A byte vector with a skewed (Huffman-friendly) symbol distribution
    /// over `alphabet` symbols — the shape real exponent streams have.
    pub fn skewed_bytes(&mut self, len: usize, alphabet: usize) -> Vec<u8> {
        let base = self.u8();
        (0..len)
            .map(|_| {
                // Geometric-ish: most mass near `base`.
                let mut off = 0usize;
                while off + 1 < alphabet && self.bool(0.45) {
                    off += 1;
                }
                base.wrapping_add(off as u8)
            })
            .collect()
    }

    /// Access the raw RNG for anything not covered above.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Run `prop` over `cases` seeded cases. Panics (with the failing seed) on
/// the first failure. The base seed is derived from the property name so
/// distinct properties explore distinct spaces but remain reproducible.
pub fn check(name: &str, cases: u64, mut prop: impl FnMut(&mut Gen)) {
    let base = fnv1a(name.as_bytes());
    for i in 0..cases {
        let case_seed = base ^ (i.wrapping_mul(0x9E3779B97F4A7C15));
        let mut g = Gen {
            rng: Rng::new(case_seed),
            case_seed,
        };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut g)));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed at case {i}/{cases} (seed {case_seed:#x}): {msg}"
            );
        }
    }
}

/// Re-run a single failing case by seed (debugging aid).
pub fn check_seed(seed: u64, mut prop: impl FnMut(&mut Gen)) {
    let mut g = Gen {
        rng: Rng::new(seed),
        case_seed: seed,
    };
    prop(&mut g);
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("u64 in range", 100, |g| {
            let x = g.u64(10..20);
            assert!((10..20).contains(&x));
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_reports_seed() {
        check("always fails", 10, |_| panic!("boom"));
    }

    #[test]
    fn skewed_bytes_are_skewed() {
        check("skewed bytes concentrate", 20, |g| {
            let v = g.skewed_bytes(2000, 8);
            let mut hist = [0usize; 256];
            for &b in &v {
                hist[b as usize] += 1;
            }
            let max = *hist.iter().max().unwrap();
            // Most common symbol holds a majority-ish share.
            assert!(max * 2 > v.len(), "max {max} of {}", v.len());
        });
    }
}
