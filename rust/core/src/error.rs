//! Error type shared by the lexi-core codecs.

use thiserror::Error;

/// Errors produced by the software codecs.
#[derive(Debug, Error, PartialEq, Eq)]
pub enum Error {
    /// The bitstream ended in the middle of a codeword or field.
    #[error("bitstream exhausted: needed {needed} more bits at offset {offset}")]
    BitstreamExhausted { offset: usize, needed: usize },

    /// A decoded codeword does not exist in the codebook.
    #[error("invalid codeword at bit offset {offset}")]
    InvalidCodeword { offset: usize },

    /// Codebook construction was handed an empty histogram.
    #[error("cannot build a codebook from an empty histogram")]
    EmptyHistogram,

    /// Codebook (de)serialization failed.
    #[error("malformed codebook header: {0}")]
    MalformedCodebook(String),

    /// Flit parsing failed.
    #[error("malformed flit: {0}")]
    MalformedFlit(String),

    /// A parameter is outside its supported range.
    #[error("invalid parameter: {0}")]
    InvalidParameter(String),
}

/// Result alias for lexi-core operations.
pub type Result<T> = std::result::Result<T, Error>;
