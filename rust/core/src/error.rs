//! Error type shared by the lexi-core codecs.
//!
//! `Display` and `std::error::Error` are implemented by hand: the offline
//! crate set has no `thiserror`, and the derive buys nothing at this size.

use std::fmt;

/// Errors produced by the software codecs.
#[derive(Debug, PartialEq, Eq)]
pub enum Error {
    /// The bitstream ended in the middle of a codeword or field.
    BitstreamExhausted { offset: usize, needed: usize },

    /// A decoded codeword does not exist in the codebook.
    InvalidCodeword { offset: usize },

    /// Codebook construction was handed an empty histogram.
    EmptyHistogram,

    /// Codebook (de)serialization failed.
    MalformedCodebook(String),

    /// Flit parsing failed.
    MalformedFlit(String),

    /// A parameter is outside its supported range.
    InvalidParameter(String),

    /// An integrity check (CRC-16) failed: the payload was corrupted in
    /// transit. `block` indexes the coded block (0 for single-block
    /// containers), `lane` the interleaved lane inside it (0 when the
    /// format has no lanes, or when the *header* itself failed).
    Corrupt { block: usize, lane: usize },
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::BitstreamExhausted { offset, needed } => write!(
                f,
                "bitstream exhausted: needed {needed} more bits at offset {offset}"
            ),
            Error::InvalidCodeword { offset } => {
                write!(f, "invalid codeword at bit offset {offset}")
            }
            Error::EmptyHistogram => {
                write!(f, "cannot build a codebook from an empty histogram")
            }
            Error::MalformedCodebook(msg) => write!(f, "malformed codebook header: {msg}"),
            Error::MalformedFlit(msg) => write!(f, "malformed flit: {msg}"),
            Error::InvalidParameter(msg) => write!(f, "invalid parameter: {msg}"),
            Error::Corrupt { block, lane } => write!(
                f,
                "integrity check failed: block {block}, lane {lane} corrupted in transit"
            ),
        }
    }
}

impl std::error::Error for Error {}

/// Result alias for lexi-core operations.
pub type Result<T> = std::result::Result<T, Error>;
