//! Error type shared by the lexi-core codecs.
//!
//! `Display` and `std::error::Error` are implemented by hand: the offline
//! crate set has no `thiserror`, and the derive buys nothing at this size.

use std::fmt;

/// Errors produced by the software codecs.
#[derive(Debug, PartialEq, Eq)]
pub enum Error {
    /// The bitstream ended in the middle of a codeword or field.
    BitstreamExhausted { offset: usize, needed: usize },

    /// A decoded codeword does not exist in the codebook.
    InvalidCodeword { offset: usize },

    /// Codebook construction was handed an empty histogram.
    EmptyHistogram,

    /// Codebook (de)serialization failed.
    MalformedCodebook(String),

    /// Flit parsing failed.
    MalformedFlit(String),

    /// A parameter is outside its supported range.
    InvalidParameter(String),

    /// An integrity check (CRC-16) failed: the payload was corrupted in
    /// transit. `block` indexes the coded block (0 for single-block
    /// containers), `lane` the interleaved lane inside it (0 when the
    /// format has no lanes, or when the *header* itself failed).
    Corrupt { block: usize, lane: usize },

    /// An ingress codec port refused an injection: the node's bounded
    /// NI queue is full because the encoder cannot keep up with the
    /// offered load. `depth` is the queue occupancy at refusal (== the
    /// configured bound). Back off and retry — nothing was enqueued.
    IngressSaturated { node: u16, depth: usize },

    /// No live route exists between two nodes (permanent link failures
    /// have disconnected them). Unlike `IngressSaturated` this is not
    /// transient: the packet can never be delivered until topology
    /// changes.
    Unreachable { src: u16, dest: u16 },

    /// Deadline-aware admission control refused the request (ISSUE 9):
    /// the serving node's bounded admission queue was at `depth`, or the
    /// predicted sojourn already exceeded the request's `deadline_ns` —
    /// a typed, counted load-shed, never an unbounded queue. Nothing
    /// was enqueued; the client may retry under its own backoff budget.
    Shed {
        node: u16,
        depth: usize,
        deadline_ns: u64,
    },
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::BitstreamExhausted { offset, needed } => write!(
                f,
                "bitstream exhausted: needed {needed} more bits at offset {offset}"
            ),
            Error::InvalidCodeword { offset } => {
                write!(f, "invalid codeword at bit offset {offset}")
            }
            Error::EmptyHistogram => {
                write!(f, "cannot build a codebook from an empty histogram")
            }
            Error::MalformedCodebook(msg) => write!(f, "malformed codebook header: {msg}"),
            Error::MalformedFlit(msg) => write!(f, "malformed flit: {msg}"),
            Error::InvalidParameter(msg) => write!(f, "invalid parameter: {msg}"),
            Error::Corrupt { block, lane } => write!(
                f,
                "integrity check failed: block {block}, lane {lane} corrupted in transit"
            ),
            Error::IngressSaturated { node, depth } => write!(
                f,
                "ingress codec port saturated at node {node}: injection queue at \
                 bound {depth}, encoder behind line rate"
            ),
            Error::Unreachable { src, dest } => write!(
                f,
                "no live route from node {src} to node {dest} (permanent link failures)"
            ),
            Error::Shed {
                node,
                depth,
                deadline_ns,
            } => write!(
                f,
                "request shed at node {node}: admission queue depth {depth} cannot \
                 meet the {deadline_ns} ns deadline"
            ),
        }
    }
}

impl std::error::Error for Error {}

/// Result alias for lexi-core operations.
pub type Result<T> = std::result::Result<T, Error>;
